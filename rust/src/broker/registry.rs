//! Broker-side records of market participants: producer usage histories
//! (the forecast inputs), resource headroom, reputation, and lease
//! bookkeeping.

use crate::broker::placement::{ConsumerRequest, ProducerState};
use crate::broker::predictor::AvailabilityPredictor;
use crate::core::{ConsumerId, Lease, ProducerId, SimTime};
use crate::util::timeseries::TimeSeries;
use std::collections::HashMap;

/// Broker-side view of one producer.
pub struct ProducerRecord {
    pub id: ProducerId,
    pub capacity_gb: f32,
    /// Usage samples (GB), 5-minute cadence by convention.
    pub usage: TimeSeries,
    /// Free slabs advertised in the latest manager report.
    pub free_slabs: u32,
    pub cpu_headroom: f64,
    pub bandwidth_headroom: f64,
    /// Slabs safe to lease per the latest forecast refresh.
    pub predicted_safe_slabs: u32,
    /// Forecast of next-step usage (for §7.2 accuracy accounting).
    pub predicted_next_usage: Option<f32>,
    /// Reputation inputs (§5: fraction of leases not broken early).
    pub slabs_leased_total: u64,
    pub slabs_broken: u64,
    /// Currently leased slabs (broker view).
    pub slabs_leased_now: u32,
    /// §7.2 accuracy: count of (checks, over-predictions by >4%).
    pub accuracy_checks: u64,
    pub overpredictions: u64,
    /// Observed data-plane p99 (µs) from the producer's last non-idle
    /// heartbeat window (0 = never observed). This is *measured* server
    /// latency, not a self-report — placement ranks by it.
    pub observed_p99_us: u64,
    /// Observed data-plane ops/sec from the last heartbeat window.
    pub observed_ops_per_sec: u64,
}

impl ProducerRecord {
    pub fn reputation(&self) -> f64 {
        if self.slabs_leased_total == 0 {
            1.0
        } else {
            1.0 - (self.slabs_broken as f64 / self.slabs_leased_total as f64).min(1.0)
        }
    }
}

/// Broker-side view of one consumer (connection credentials are opaque
/// here; the broker only brokers, §3).
#[derive(Clone, Debug, Default)]
pub struct ConsumerRecord {
    pub leases_active: u32,
    pub slabs_active: u32,
}

/// Participant registry.
#[derive(Default)]
pub struct Registry {
    producers: HashMap<ProducerId, ProducerRecord>,
    consumers: HashMap<ConsumerId, ConsumerRecord>,
}

impl Registry {
    pub fn register_producer(&mut self, id: ProducerId, capacity_gb: f32) {
        self.producers.entry(id).or_insert_with(|| ProducerRecord {
            id,
            capacity_gb,
            usage: TimeSeries::new(288),
            free_slabs: 0,
            cpu_headroom: 1.0,
            bandwidth_headroom: 1.0,
            predicted_safe_slabs: 0,
            predicted_next_usage: None,
            slabs_leased_total: 0,
            slabs_broken: 0,
            slabs_leased_now: 0,
            accuracy_checks: 0,
            overpredictions: 0,
            observed_p99_us: 0,
            observed_ops_per_sec: 0,
        });
    }

    pub fn deregister_producer(&mut self, id: ProducerId) {
        self.producers.remove(&id);
    }

    pub fn register_consumer(&mut self, id: ConsumerId) {
        self.consumers.entry(id).or_default();
    }

    pub fn producer_count(&self) -> usize {
        self.producers.len()
    }
    pub fn consumer_count(&self) -> usize {
        self.consumers.len()
    }

    /// Periodic usage report (§3): appended to the forecast history, and
    /// scored against the previous prediction (§7.2 accuracy).
    pub fn report_usage(&mut self, id: ProducerId, _now: SimTime, used_gb: f32) {
        if let Some(p) = self.producers.get_mut(&id) {
            if let Some(pred) = p.predicted_next_usage.take() {
                p.accuracy_checks += 1;
                // §7.2: an over-prediction is counted when the forecast
                // exceeds actual usage by more than 4% of VM capacity.
                if pred > used_gb + 0.04 * p.capacity_gb {
                    p.overpredictions += 1;
                }
            }
            p.usage.push(used_gb);
        }
    }

    /// Heartbeat-carried observed telemetry: the producer's measured
    /// data-plane tail latency and throughput over its last window. An
    /// idle window (p99 = 0) keeps the previous latency evidence — no
    /// new traffic is not evidence of being fast.
    pub fn report_observed_telemetry(&mut self, id: ProducerId, p99_us: u64, ops_per_sec: u64) {
        if let Some(p) = self.producers.get_mut(&id) {
            p.observed_ops_per_sec = ops_per_sec;
            if p99_us > 0 {
                p.observed_p99_us = p99_us;
            }
        }
    }

    /// Manager resource report: free slabs + headroom.
    pub fn update_producer_resources(
        &mut self,
        id: ProducerId,
        free_slabs: u32,
        cpu_headroom: f64,
        bandwidth_headroom: f64,
    ) {
        if let Some(p) = self.producers.get_mut(&id) {
            p.free_slabs = free_slabs;
            p.cpu_headroom = cpu_headroom;
            p.bandwidth_headroom = bandwidth_headroom;
        }
    }

    pub fn note_lease(&mut self, lease: &Lease) {
        if let Some(p) = self.producers.get_mut(&lease.producer) {
            p.slabs_leased_total += lease.slabs as u64;
            p.slabs_leased_now += lease.slabs;
            p.free_slabs = p.free_slabs.saturating_sub(lease.slabs);
        }
        if let Some(c) = self.consumers.get_mut(&lease.consumer) {
            c.leases_active += 1;
            c.slabs_active += lease.slabs;
        }
    }

    pub fn note_lease_end(&mut self, lease: &Lease, broken: bool) {
        if let Some(p) = self.producers.get_mut(&lease.producer) {
            p.slabs_leased_now = p.slabs_leased_now.saturating_sub(lease.slabs);
            if broken {
                p.slabs_broken += lease.slabs as u64;
            }
        }
        if let Some(c) = self.consumers.get_mut(&lease.consumer) {
            c.leases_active = c.leases_active.saturating_sub(1);
            c.slabs_active = c.slabs_active.saturating_sub(lease.slabs);
        }
    }

    pub fn producer(&self, id: ProducerId) -> Option<&ProducerRecord> {
        self.producers.get(&id)
    }

    pub fn producers_mut(&mut self) -> impl Iterator<Item = &mut ProducerRecord> {
        self.producers.values_mut()
    }

    pub fn producers(&self) -> impl Iterator<Item = &ProducerRecord> {
        self.producers.values()
    }

    /// Snapshot the placement inputs for one request (§5.2).
    pub fn producer_states(
        &self,
        _predictor: &AvailabilityPredictor,
        request: &ConsumerRequest,
        _now: SimTime,
    ) -> Vec<ProducerState> {
        self.producers
            .values()
            .map(|p| ProducerState {
                producer: p.id,
                free_slabs: p.free_slabs,
                predicted_safe_slabs: p.predicted_safe_slabs,
                cpu_headroom: p.cpu_headroom,
                bandwidth_headroom: p.bandwidth_headroom,
                // Latency evidence, best first: the consumer's own
                // measurement to this producer, else the broker's
                // *observed* data-plane p99 from heartbeats, else the
                // legacy default. A producer whose store is actually
                // slow loses placement share even when it self-reports
                // healthy headroom.
                latency_us: request.latency_us_to.get(&p.id).copied().unwrap_or(
                    if p.observed_p99_us > 0 { p.observed_p99_us } else { 200 },
                ),
                reputation: p.reputation(),
            })
            .collect()
    }

    /// §7.2 accuracy aggregates: (checks, overpredictions).
    pub fn prediction_accuracy(&self) -> (u64, u64) {
        let mut checks = 0;
        let mut over = 0;
        for p in self.producers.values() {
            checks += p.accuracy_checks;
            over += p.overpredictions;
        }
        (checks, over)
    }

    /// Fraction of leased slabs broken early, cluster-wide.
    pub fn broken_fraction(&self) -> f64 {
        let total: u64 = self.producers.values().map(|p| p.slabs_leased_total).sum();
        let broken: u64 = self.producers.values().map(|p| p.slabs_broken).sum();
        if total == 0 {
            0.0
        } else {
            broken as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{LeaseId, Money, DEFAULT_SLAB_BYTES};

    fn lease(producer: u64, consumer: u64, slabs: u32) -> Lease {
        Lease {
            id: LeaseId(1),
            consumer: ConsumerId(consumer),
            producer: ProducerId(producer),
            slabs,
            slab_bytes: DEFAULT_SLAB_BYTES,
            start: SimTime::ZERO,
            duration: SimTime::from_hours(1),
            price_per_slab_hour: Money::from_dollars(0.0001),
        }
    }

    #[test]
    fn lease_bookkeeping_and_reputation() {
        let mut r = Registry::default();
        r.register_producer(ProducerId(1), 32.0);
        r.register_consumer(ConsumerId(9));
        r.update_producer_resources(ProducerId(1), 64, 0.9, 0.9);
        let l = lease(1, 9, 16);
        r.note_lease(&l);
        let p = r.producer(ProducerId(1)).unwrap();
        assert_eq!(p.free_slabs, 48);
        assert_eq!(p.slabs_leased_now, 16);
        assert_eq!(p.reputation(), 1.0);
        r.note_lease_end(&l, true);
        let p = r.producer(ProducerId(1)).unwrap();
        assert_eq!(p.slabs_leased_now, 0);
        assert!((p.reputation() - 0.0).abs() < 1e-12); // all slabs broken
        assert!((r.broken_fraction() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn accuracy_scoring() {
        let mut r = Registry::default();
        r.register_producer(ProducerId(1), 32.0);
        // Prediction 10 GB, actual 8 GB -> overprediction by 25% (> 4%).
        r.producers_mut().next().unwrap().predicted_next_usage = Some(10.0);
        r.report_usage(ProducerId(1), SimTime::ZERO, 8.0);
        // Prediction 8.1 GB, actual 8.0 -> within 4%.
        r.producers_mut().next().unwrap().predicted_next_usage = Some(8.1);
        r.report_usage(ProducerId(1), SimTime::ZERO, 8.0);
        assert_eq!(r.prediction_accuracy(), (2, 1));
    }

    #[test]
    fn observed_telemetry_feeds_placement_latency() {
        use crate::broker::placement;
        use crate::core::config::PlacementWeights;
        let mut r = Registry::default();
        r.register_producer(ProducerId(1), 32.0);
        r.register_producer(ProducerId(2), 32.0);
        for id in [1u64, 2] {
            r.update_producer_resources(ProducerId(id), 16, 0.9, 0.9);
            r.producers_mut().find(|p| p.id.0 == id).unwrap().predicted_safe_slabs = 16;
        }
        // Producer 2's store is observed slow; producer 1 fast.
        r.report_observed_telemetry(ProducerId(2), 8_000, 500);
        r.report_observed_telemetry(ProducerId(1), 80, 5_000);
        let req = crate::broker::ConsumerRequest {
            consumer: ConsumerId(9),
            slabs: 8,
            min_slabs: 1,
            lease: SimTime::from_hours(1),
            max_price_per_slab_hour: None,
            latency_us_to: Default::default(),
            weights: None,
        };
        let states = r.producer_states(
            &crate::broker::AvailabilityPredictor::fallback(288, 12),
            &req,
            SimTime::ZERO,
        );
        let p1 = states.iter().find(|s| s.producer.0 == 1).unwrap();
        let p2 = states.iter().find(|s| s.producer.0 == 2).unwrap();
        assert_eq!(p1.latency_us, 80);
        assert_eq!(p2.latency_us, 8_000);
        let ranked = placement::rank(&states, &req, &PlacementWeights::default());
        assert_eq!(ranked[0].producer, ProducerId(1), "observed-slow producer ranked first");
        // An idle window (p99 = 0) keeps the previous evidence.
        r.report_observed_telemetry(ProducerId(2), 0, 0);
        assert_eq!(r.producer(ProducerId(2)).unwrap().observed_p99_us, 8_000);
        // A consumer's own measurement still wins over observed p99.
        let mut req2 = req.clone();
        req2.latency_us_to.insert(ProducerId(2), 50);
        let states = r.producer_states(
            &crate::broker::AvailabilityPredictor::fallback(288, 12),
            &req2,
            SimTime::ZERO,
        );
        assert_eq!(states.iter().find(|s| s.producer.0 == 2).unwrap().latency_us, 50);
    }

    #[test]
    fn deregister() {
        let mut r = Registry::default();
        r.register_producer(ProducerId(1), 16.0);
        assert_eq!(r.producer_count(), 1);
        r.deregister_producer(ProducerId(1));
        assert_eq!(r.producer_count(), 0);
    }
}

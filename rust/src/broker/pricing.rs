//! Pricing engine (paper §5.3 / §7.4).
//!
//! Strategies:
//! * **FixedFraction** — the baseline: price = ¼ of the current spot
//!   price per GB·hour, tracked each epoch.
//! * **MaxVolume** / **MaxRevenue** — local search: evaluate candidate
//!   prices {p-Δp, p, p+Δp} against the consumer demand curves (via the
//!   AOT demand artifact, or the Rust mirror) and move to the candidate
//!   maximizing the objective. Δp defaults to the paper's 0.002 ¢/GB·h.
//!
//! The price is always capped at the spot price (a consumer could rent a
//! whole spot instance instead, §5.3) and floored at zero.

use crate::broker::registry::Registry;
use crate::core::{Money, GIB};
use crate::runtime::arima_fallback;
use crate::runtime::engine::{DemandEngine, DEMAND_PRICES, DEMAND_SIZES};

/// Economic objective for price adjustment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PricingStrategy {
    /// Track ¼ of spot, no search.
    FixedFraction,
    /// Maximize total slabs traded.
    MaxVolume,
    /// Maximize total producer revenue (the broker's default — its
    /// commission is proportional).
    MaxRevenue,
}

enum DemandBackend {
    Pjrt(DemandEngine),
    Fallback,
}

/// Demand-side inputs for one pricing epoch: each consumer's gain curve
/// (extra hits/sec at s extra slabs) and per-hit value.
#[derive(Clone, Debug, Default)]
pub struct DemandInputs {
    pub gains: Vec<Vec<f32>>,
    pub hit_values: Vec<f32>,
}

impl DemandInputs {
    pub fn push(&mut self, gain: Vec<f32>, hit_value: f32) {
        debug_assert_eq!(gain.len(), DEMAND_SIZES);
        self.gains.push(gain);
        self.hit_values.push(hit_value);
    }
    pub fn len(&self) -> usize {
        self.gains.len()
    }
    pub fn is_empty(&self) -> bool {
        self.gains.is_empty()
    }
}

/// Result of one pricing evaluation (per candidate).
#[derive(Clone, Copy, Debug, Default)]
pub struct MarketEval {
    pub volume: f64,
    pub revenue: f64,
}

pub struct PricingEngine {
    strategy: PricingStrategy,
    price_per_slab_hour: Money,
    step: Money,
    backend: DemandBackend,
    /// Latest demand inputs installed by the market simulation.
    demand_inputs: DemandInputs,
    /// Diagnostics: evaluations per epoch and last evals.
    pub last_evals: [MarketEval; DEMAND_PRICES],
    pub epochs: u64,
}

impl PricingEngine {
    pub fn new(strategy: PricingStrategy, initial_price: Money, step_dollars_per_gb: f64) -> Self {
        PricingEngine {
            strategy,
            price_per_slab_hour: initial_price,
            // Δp is quoted per GB·hour in the paper; convert to per slab.
            step: Money::from_dollars(step_dollars_per_gb * slab_gb()),
            backend: DemandBackend::Fallback,
            demand_inputs: DemandInputs::default(),
            last_evals: [MarketEval::default(); DEMAND_PRICES],
            epochs: 0,
        }
    }

    pub fn with_engine(mut self, engine: DemandEngine) -> Self {
        self.backend = DemandBackend::Pjrt(engine);
        self
    }

    pub fn strategy(&self) -> PricingStrategy {
        self.strategy
    }

    pub fn current_price(&self) -> Money {
        self.price_per_slab_hour
    }

    pub fn set_price(&mut self, p: Money) {
        self.price_per_slab_hour = p;
    }

    /// Install this epoch's demand curves (from the market simulation or
    /// real consumer reports).
    pub fn set_demand_inputs(&mut self, inputs: DemandInputs) {
        self.demand_inputs = inputs;
    }

    /// Evaluate candidates {p-Δ, p, p+Δ} against current demand inputs.
    pub fn evaluate_candidates(
        &mut self,
        prices: [f64; DEMAND_PRICES],
    ) -> [MarketEval; DEMAND_PRICES] {
        if self.demand_inputs.is_empty() {
            return [MarketEval::default(); DEMAND_PRICES];
        }
        match &self.backend {
            DemandBackend::Pjrt(engine) => {
                let p32 = [prices[0] as f32, prices[1] as f32, prices[2] as f32];
                let result = engine
                    .evaluate(&self.demand_inputs.gains, &self.demand_inputs.hit_values, p32)
                    .expect("PJRT demand execution failed");
                std::array::from_fn(|k| MarketEval {
                    volume: result.volume[k],
                    revenue: result.revenue[k],
                })
            }
            DemandBackend::Fallback => std::array::from_fn(|k| {
                let mut volume = 0f64;
                for (gain, &value) in self
                    .demand_inputs
                    .gains
                    .iter()
                    .zip(&self.demand_inputs.hit_values)
                {
                    volume += arima_fallback::demand_one(gain, value, prices[k]) as f64;
                }
                MarketEval { volume, revenue: volume * prices[k] }
            }),
        }
    }

    /// One pricing epoch (§5.3): adjust the price per the strategy.
    /// `spot` is the current spot price per GB·hour.
    pub fn adjust(&mut self, _registry: &Registry, spot_per_gb_hour: Money, slab_bytes: u64) {
        self.epochs += 1;
        let slab_frac = slab_bytes as f64 / GIB as f64;
        let spot_per_slab = spot_per_gb_hour.scale(slab_frac);
        match self.strategy {
            PricingStrategy::FixedFraction => {
                self.price_per_slab_hour = spot_per_slab.scale(0.25);
            }
            PricingStrategy::MaxVolume | PricingStrategy::MaxRevenue => {
                let p = self.price_per_slab_hour.as_dollars();
                let dp = self.step.as_dollars().max(1e-9);
                let candidates = [(p - dp).max(0.0), p, p + dp];
                let evals = self.evaluate_candidates(candidates);
                self.last_evals = evals;
                let key = |e: &MarketEval| match self.strategy {
                    PricingStrategy::MaxVolume => e.volume,
                    _ => e.revenue,
                };
                let mut best = 1; // stay put on ties
                for k in 0..DEMAND_PRICES {
                    if key(&evals[k]) > key(&evals[best]) {
                        best = k;
                    }
                }
                self.price_per_slab_hour = Money::from_dollars(candidates[best]);
            }
        }
        // Never exceed spot (the consumer's outside option); never fall
        // below a small floor (2% of spot) covering market operating
        // costs — this keeps the max-volume strategy from racing to zero.
        let floor = spot_per_slab.scale(0.02);
        if self.price_per_slab_hour > spot_per_slab {
            self.price_per_slab_hour = spot_per_slab;
        }
        if self.price_per_slab_hour < floor {
            self.price_per_slab_hour = floor;
        }
    }
}

fn slab_gb() -> f64 {
    crate::core::DEFAULT_SLAB_BYTES as f64 / GIB as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::DEFAULT_SLAB_BYTES;

    fn concave_gain(rate: f64, knee: f64) -> Vec<f32> {
        (0..DEMAND_SIZES)
            .map(|s| (rate * (1.0 - (-(s as f64) / knee).exp())) as f32)
            .collect()
    }

    fn inputs(n: usize) -> DemandInputs {
        let mut d = DemandInputs::default();
        for i in 0..n {
            d.push(concave_gain(500.0 + i as f64, 10.0), 1e-4);
        }
        d
    }

    #[test]
    fn fixed_fraction_tracks_spot() {
        let mut e = PricingEngine::new(PricingStrategy::FixedFraction, Money::ZERO, 0.00002);
        let reg = Registry::default();
        e.adjust(&reg, Money::from_dollars(0.0040), DEFAULT_SLAB_BYTES);
        // slab = 1/16 GB; spot/slab = 0.00025; quarter = 0.0000625.
        assert!((e.current_price().as_dollars() - 0.0000625).abs() < 1e-9);
    }

    #[test]
    fn search_moves_toward_objective() {
        let mut e = PricingEngine::new(
            PricingStrategy::MaxRevenue,
            Money::from_dollars(0.00001),
            0.00002,
        );
        e.set_demand_inputs(inputs(100));
        let reg = Registry::default();
        let mut last = e.current_price();
        // Revenue at tiny prices grows with price (demand barely falls):
        // the search should walk upward.
        for _ in 0..10 {
            e.adjust(&reg, Money::from_dollars(1.0), DEFAULT_SLAB_BYTES);
        }
        assert!(e.current_price() > last, "price did not rise: {}", e.current_price());
        last = e.current_price();
        let _ = last;
    }

    #[test]
    fn price_capped_at_spot() {
        let mut e = PricingEngine::new(
            PricingStrategy::MaxRevenue,
            Money::from_dollars(100.0),
            0.00002,
        );
        e.set_demand_inputs(inputs(10));
        let reg = Registry::default();
        e.adjust(&reg, Money::from_dollars(0.0040), DEFAULT_SLAB_BYTES);
        let spot_per_slab = 0.0040 / 16.0;
        assert!(e.current_price().as_dollars() <= spot_per_slab + 1e-12);
    }

    #[test]
    fn volume_vs_revenue_objectives_differ() {
        // With demand that collapses above a threshold price, MaxVolume
        // stays low while MaxRevenue pushes to just under the cliff.
        let mut vol = PricingEngine::new(
            PricingStrategy::MaxVolume,
            Money::from_dollars(0.0001),
            0.00002,
        );
        let mut rev = PricingEngine::new(
            PricingStrategy::MaxRevenue,
            Money::from_dollars(0.0001),
            0.00002,
        );
        let reg = Registry::default();
        for _ in 0..50 {
            vol.set_demand_inputs(inputs(50));
            rev.set_demand_inputs(inputs(50));
            vol.adjust(&reg, Money::from_dollars(1.0), DEFAULT_SLAB_BYTES);
            rev.adjust(&reg, Money::from_dollars(1.0), DEFAULT_SLAB_BYTES);
        }
        assert!(rev.current_price() >= vol.current_price());
    }

    #[test]
    fn empty_demand_keeps_price_within_floor_and_cap() {
        let mut e = PricingEngine::new(
            PricingStrategy::MaxRevenue,
            Money::from_dollars(0.005),
            0.00002,
        );
        let reg = Registry::default();
        // With no demand inputs the search leaves the price alone (it sits
        // between the 2%-of-spot floor and the spot cap).
        e.adjust(&reg, Money::from_dollars(1.0), DEFAULT_SLAB_BYTES);
        assert!((e.current_price().as_dollars() - 0.005).abs() < 1e-9);
        // Below the floor it is raised to the floor.
        e.set_price(Money::from_dollars(1e-9));
        e.adjust(&reg, Money::from_dollars(1.0), DEFAULT_SLAB_BYTES);
        let floor = (1.0 / 16.0) * 0.02;
        assert!((e.current_price().as_dollars() - floor).abs() < 1e-9);
    }
}

//! The broker — the trusted third party at the center of the market
//! (paper §5): it registers producers and consumers, tracks producer
//! usage histories, predicts availability with the AOT forecast artifact
//! (§5.1), places consumer requests onto producers with a weighted greedy
//! algorithm and FIFO pending queue (§5.2), and sets the market price
//! (§5.3) — fixed fraction-of-spot, max-trading-volume, or max-revenue
//! via {p-Δ, p, p+Δ} local search evaluated by the demand artifact.

pub mod placement;
pub mod predictor;
pub mod pricing;
pub mod registry;

pub use placement::{ConsumerRequest, PlacementOutcome, ProducerState};
pub use predictor::AvailabilityPredictor;
pub use pricing::{PricingEngine, PricingStrategy};
pub use registry::Registry;

use crate::core::config::BrokerConfig;
use crate::core::{Lease, LeaseId, Money, SimTime};
use std::collections::VecDeque;

/// Aggregate broker statistics (Fig 10, §7.2).
#[derive(Clone, Debug, Default)]
pub struct BrokerStats {
    pub requests: u64,
    pub slabs_requested: u64,
    pub slabs_granted: u64,
    pub requests_fully_satisfied: u64,
    pub requests_partially_satisfied: u64,
    pub requests_queued: u64,
    pub requests_expired: u64,
    pub leases_granted: u64,
    pub commission_earned: Money,
}

impl crate::metrics::Observe for BrokerStats {
    fn observe(&self, prefix: &str, out: &mut crate::metrics::MetricSet) {
        use crate::metrics::scoped;
        out.set_counter(scoped(prefix, "requests"), self.requests);
        out.set_counter(scoped(prefix, "slabs_requested"), self.slabs_requested);
        out.set_counter(scoped(prefix, "slabs_granted"), self.slabs_granted);
        out.set_counter(scoped(prefix, "requests_fully_satisfied"), self.requests_fully_satisfied);
        out.set_counter(
            scoped(prefix, "requests_partially_satisfied"),
            self.requests_partially_satisfied,
        );
        out.set_counter(scoped(prefix, "requests_queued"), self.requests_queued);
        out.set_counter(scoped(prefix, "requests_expired"), self.requests_expired);
        out.set_counter(scoped(prefix, "leases_granted"), self.leases_granted);
        out.set_gauge(scoped(prefix, "commission_earned_nd"), self.commission_earned.0);
    }
}

struct PendingRequest {
    request: ConsumerRequest,
    remaining_slabs: u32,
    enqueued: SimTime,
}

/// The market coordinator.
pub struct Broker {
    pub cfg: BrokerConfig,
    pub registry: Registry,
    pub predictor: AvailabilityPredictor,
    pub pricing: PricingEngine,
    pending: VecDeque<PendingRequest>,
    next_lease: u64,
    pub stats: BrokerStats,
}

impl Broker {
    pub fn new(
        cfg: BrokerConfig,
        predictor: AvailabilityPredictor,
        pricing: PricingEngine,
    ) -> Self {
        Broker {
            cfg,
            registry: Registry::default(),
            predictor,
            pricing,
            pending: VecDeque::new(),
            next_lease: 1,
            stats: BrokerStats::default(),
        }
    }

    pub fn current_price(&self) -> Money {
        self.pricing.current_price()
    }

    /// Handle one consumer allocation request (paper §5.2): greedy
    /// placement over registered producers; unfilled remainder queued.
    pub fn request_memory(&mut self, now: SimTime, request: ConsumerRequest) -> Vec<Lease> {
        self.stats.requests += 1;
        self.stats.slabs_requested += request.slabs as u64;
        let (leases, granted) = self.place(now, &request, request.slabs);
        if granted == request.slabs {
            self.stats.requests_fully_satisfied += 1;
        } else if granted >= request.min_slabs && granted > 0 {
            self.stats.requests_partially_satisfied += 1;
            self.queue_remainder(now, &request, request.slabs - granted);
        } else if granted == 0 {
            self.stats.requests_queued += 1;
            self.queue_remainder(now, &request, request.slabs);
        }
        leases
    }

    fn queue_remainder(&mut self, now: SimTime, request: &ConsumerRequest, remaining: u32) {
        self.pending.push_back(PendingRequest {
            request: request.clone(),
            remaining_slabs: remaining,
            enqueued: now,
        });
    }

    /// Greedy placement of up to `want` slabs; returns (leases, granted).
    fn place(&mut self, now: SimTime, request: &ConsumerRequest, want: u32) -> (Vec<Lease>, u32) {
        let price = self.pricing.current_price();
        // Budget check (§5.2: price must not exceed the consumer budget).
        if let Some(budget) = request.max_price_per_slab_hour {
            if price > budget {
                return (Vec::new(), 0);
            }
        }
        let states = self.registry.producer_states(&self.predictor, request, now);
        let ranked = placement::rank(&states, request, &self.cfg.weights);
        let mut leases = Vec::new();
        let mut granted = 0u32;
        for state in ranked {
            if granted >= want {
                break;
            }
            let can_give = state.grantable_slabs().min(want - granted);
            if can_give == 0 {
                continue;
            }
            let lease = Lease {
                id: LeaseId(self.next_lease),
                consumer: request.consumer,
                producer: state.producer,
                slabs: can_give,
                slab_bytes: self.cfg.slab_bytes,
                start: now,
                duration: request.lease.max(self.cfg.min_lease),
                price_per_slab_hour: price,
            };
            self.next_lease += 1;
            granted += can_give;
            self.registry.note_lease(&lease);
            self.stats.leases_granted += 1;
            self.stats.slabs_granted += can_give as u64;
            self.stats.commission_earned += lease.total_cost().scale(self.cfg.commission);
            leases.push(lease);
        }
        (leases, granted)
    }

    /// One market epoch (§5): refresh availability predictions, retry the
    /// pending queue FIFO, expire stale entries, adjust the price.
    pub fn market_epoch(&mut self, now: SimTime, spot_per_gb_hour: Money) -> Vec<Lease> {
        self.predictor.refresh(&mut self.registry, now);
        self.pricing.adjust(&self.registry, spot_per_gb_hour, self.cfg.slab_bytes);
        self.service_pending(now)
    }

    /// Retry the pending queue FIFO and expire stale entries, without
    /// touching predictions or price (the networked broker daemon runs
    /// those on its own cadence).
    pub fn service_pending(&mut self, now: SimTime) -> Vec<Lease> {
        let mut granted_leases = Vec::new();
        let mut still_pending = VecDeque::new();
        while let Some(mut p) = self.pending.pop_front() {
            if now.saturating_sub(p.enqueued) > self.cfg.pending_timeout {
                self.stats.requests_expired += 1;
                continue;
            }
            let (leases, granted) = self.place(now, &p.request, p.remaining_slabs);
            granted_leases.extend(leases);
            if granted < p.remaining_slabs {
                p.remaining_slabs -= granted;
                still_pending.push_back(p);
            }
        }
        self.pending = still_pending;
        granted_leases
    }

    /// A lease ended (expired or consumer released it).
    pub fn lease_ended(&mut self, lease: &Lease, broken: bool) {
        self.registry.note_lease_end(lease, broken);
    }

    /// Adopt a lease granted elsewhere — a warm standby replaying the
    /// primary's replication log. Accounts it in the registry exactly
    /// as [`Self::request_memory`] would (so the symmetric
    /// [`Self::lease_ended`] stays balanced) and advances the id
    /// counter past it, so grants made after takeover can never
    /// collide with a replicated lease id.
    pub fn adopt_lease(&mut self, lease: &Lease) {
        self.next_lease = self.next_lease.max(lease.id.0 + 1);
        self.registry.note_lease(lease);
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Drop every queued remainder. The broker daemon has no push channel
    /// to consumers, so it must not grant invisibly from the queue later;
    /// consumer pools re-request instead (§5.2's FIFO queue lives in the
    /// pool's retry loop there).
    pub fn drain_pending(&mut self) -> usize {
        let n = self.pending.len();
        self.pending.clear();
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ConsumerId, ProducerId, GIB};

    fn broker() -> Broker {
        let cfg = BrokerConfig::default();
        let predictor = AvailabilityPredictor::fallback(288, 12);
        let pricing = PricingEngine::new(
            PricingStrategy::FixedFraction,
            Money::from_dollars(0.0005),
            cfg.price_step_dollars,
        );
        Broker::new(cfg, predictor, pricing)
    }

    fn request(consumer: u64, slabs: u32) -> ConsumerRequest {
        ConsumerRequest {
            consumer: ConsumerId(consumer),
            slabs,
            min_slabs: 1,
            lease: SimTime::from_hours(1),
            max_price_per_slab_hour: None,
            latency_us_to: Default::default(),
            weights: None,
        }
    }

    fn feed_producer(b: &mut Broker, id: u64, cap_gb: f32, used_gb: f32, free_slabs: u32) {
        b.registry.register_producer(ProducerId(id), cap_gb);
        for t in 0..300 {
            b.registry.report_usage(ProducerId(id), SimTime::from_secs(t * 300), used_gb);
        }
        b.registry.update_producer_resources(ProducerId(id), free_slabs, 0.8, 0.8);
        b.predictor.refresh(&mut b.registry, SimTime::from_hours(25));
    }

    #[test]
    fn grants_up_to_free_slabs() {
        let mut b = broker();
        feed_producer(&mut b, 1, 32.0, 8.0, 64);
        let leases = b.request_memory(SimTime::from_hours(25), request(1, 32));
        let total: u32 = leases.iter().map(|l| l.slabs).sum();
        assert_eq!(total, 32);
        assert_eq!(b.stats.requests_fully_satisfied, 1);
    }

    #[test]
    fn splits_across_producers_lowest_cost_first() {
        let mut b = broker();
        feed_producer(&mut b, 1, 32.0, 8.0, 16);
        feed_producer(&mut b, 2, 32.0, 8.0, 16);
        let leases = b.request_memory(SimTime::from_hours(25), request(1, 24));
        assert!(leases.len() >= 2, "should span producers: {leases:?}");
        let total: u32 = leases.iter().map(|l| l.slabs).sum();
        assert_eq!(total, 24);
    }

    #[test]
    fn queues_when_unsatisfied_and_retries_on_epoch() {
        let mut b = broker();
        feed_producer(&mut b, 1, 32.0, 8.0, 4);
        let leases = b.request_memory(SimTime::from_hours(25), request(1, 64));
        let got: u32 = leases.iter().map(|l| l.slabs).sum();
        assert_eq!(got, 4);
        assert_eq!(b.pending_len(), 1);
        // New capacity appears; epoch services the queue.
        b.registry.update_producer_resources(ProducerId(1), 128, 0.8, 0.8);
        let more = b.market_epoch(
            SimTime::from_hours(25) + SimTime::from_mins(5),
            Money::from_dollars(0.002),
        );
        let got2: u32 = more.iter().map(|l| l.slabs).sum();
        assert_eq!(got2, 60);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn pending_expires() {
        let mut b = broker();
        // No producers at all -> queued.
        b.request_memory(SimTime::from_hours(1), request(1, 8));
        assert_eq!(b.pending_len(), 1);
        b.market_epoch(SimTime::from_hours(3), Money::from_dollars(0.002));
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.stats.requests_expired, 1);
    }

    #[test]
    fn respects_budget() {
        let mut b = broker();
        feed_producer(&mut b, 1, 32.0, 8.0, 64);
        let mut req = request(1, 8);
        req.max_price_per_slab_hour = Some(Money::from_dollars(1e-9));
        let leases = b.request_memory(SimTime::from_hours(25), req);
        assert!(leases.is_empty());
    }

    #[test]
    fn adopted_leases_never_collide_with_fresh_grants() {
        let mut b = broker();
        feed_producer(&mut b, 1, 32.0, 8.0, 64);
        // Replay a lease the (dead) primary granted as id 41.
        let adopted = Lease {
            id: LeaseId(41),
            consumer: ConsumerId(9),
            producer: ProducerId(1),
            slabs: 8,
            slab_bytes: b.cfg.slab_bytes,
            start: SimTime::ZERO,
            duration: SimTime::from_hours(1),
            price_per_slab_hour: Money::from_dollars(0.0001),
        };
        b.adopt_lease(&adopted);
        let p = b.registry.producer(ProducerId(1)).unwrap();
        assert_eq!(p.slabs_leased_now, 8);
        assert_eq!(p.free_slabs, 56);
        // Post-takeover grants start past the adopted id.
        let leases = b.request_memory(SimTime::from_hours(25), request(1, 4));
        assert_eq!(leases[0].id, LeaseId(42));
        // The symmetric end leaves the registry balanced.
        b.lease_ended(&adopted, false);
        assert_eq!(b.registry.producer(ProducerId(1)).unwrap().slabs_leased_now, 4);
    }

    #[test]
    fn lease_sizing_uses_gib() {
        let mut b = broker();
        feed_producer(&mut b, 1, 32.0, 8.0, 64);
        let leases = b.request_memory(SimTime::from_hours(25), request(1, 16));
        assert_eq!(leases[0].bytes(), GIB);
    }
}

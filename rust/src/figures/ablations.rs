//! Ablations for the design choices DESIGN.md calls out (not in the
//! paper's evaluation, but claimed by its design sections):
//!
//! * `ablation_silo` — the adaptive harvester with vs without Silo
//!   (§4.1 claims Silo is what makes aggressive harvesting safe).
//! * `ablation_baseline` — the no-page-in baseline filter of Algorithm 1
//!   vs a naive all-samples baseline (§4.1 "Estimating the Baseline").
//! * `ablation_placement` — placement with vs without the predicted-
//!   availability term (§5.2): broken leases should rise without it.
//! * `fig14` — appendix: memory composition over time for all six apps.

use crate::core::config::HarvesterConfig;
use crate::core::{ProducerId, SimTime, GIB, MIB};
use crate::mem::SwapDevice;
use crate::util::fmt::{gb, pct, Table};
use crate::producer::Producer;
use crate::sim::replay::{run as replay, ReplayConfig};
use crate::workload::apps::{AppKind, AppModel, AppRunner};

fn adaptive(kind: AppKind, silo: bool, minutes: u64, quick: bool) -> (f64, f64, f64) {
    let page = if quick { 16 * MIB } else { 4 * MIB };
    let mut app = AppRunner::new(
        AppModel::preset(kind),
        page,
        SwapDevice::Ssd,
        silo.then(|| SimTime::from_mins(5)),
        19,
    );
    app.ops_cap_per_epoch = if quick { 250 } else { 1000 };
    let baseline = app.baseline_latency_us();
    let mut p = Producer::new(ProducerId(1), app, HarvesterConfig::default(), 64 * MIB);
    let epoch = SimTime::from_secs(5);
    let epochs = minutes * 12;
    let mut sum = 0.0;
    let mut n = 0u64;
    for e in 1..=epochs {
        let lat = p.tick(SimTime::from_micros(e * epoch.as_micros()), epoch);
        if e > epochs / 2 {
            sum += lat;
            n += 1;
        }
    }
    let harvested = p.app.memory.shape().harvestable as f64 / GIB as f64;
    (harvested, baseline, sum / n as f64)
}

/// Silo on/off under the *adaptive* harvester (Fig 6 is static sweeps).
pub fn ablation_silo(quick: bool) -> Vec<Table> {
    let minutes = if quick { 25 } else { 90 };
    let mut t = Table::new(vec![
        "app",
        "harvested w/ Silo (GB)",
        "perf drop w/ Silo",
        "harvested w/o Silo (GB)",
        "perf drop w/o Silo",
    ]);
    for kind in [AppKind::Redis, AppKind::Memcached, AppKind::Storm] {
        let (h1, b1, l1) = adaptive(kind, true, minutes, quick);
        let (h0, b0, l0) = adaptive(kind, false, minutes, quick);
        t.row(vec![
            kind.name().to_string(),
            format!("{h1:.2}"),
            pct((l1 / b1 - 1.0).max(0.0)),
            format!("{h0:.2}"),
            pct((l0 / b0 - 1.0).max(0.0)),
        ]);
    }
    vec![t]
}

/// Baseline estimator ablation: Algorithm 1 only adds samples to the
/// baseline when an epoch saw no page-ins. A naive estimator that admits
/// every sample lets degraded performance *become* the baseline, so the
/// drop detector stops firing and the harvester over-harvests.
pub fn ablation_baseline(quick: bool) -> Vec<Table> {
    use crate::util::avl::WindowedDist;
    let minutes = if quick { 25 } else { 90 };
    let page = if quick { 16 * MIB } else { 4 * MIB };

    // Proper harvester (page-in filtered baseline).
    let (h_proper, base, steady) = adaptive(AppKind::Redis, true, minutes, quick);

    // Naive variant, driven directly: baseline admits every sample.
    let mut app = AppRunner::new(
        AppModel::preset(AppKind::Redis),
        page,
        SwapDevice::Ssd,
        Some(SimTime::from_mins(5)),
        19,
    );
    app.ops_cap_per_epoch = if quick { 250 } else { 1000 };
    let cfg = HarvesterConfig::default();
    let mut naive_baseline = WindowedDist::new(cfg.window_size);
    let mut recent = WindowedDist::new(cfg.window_size);
    let mut limit = app.model.vm_bytes;
    let mut last_reclaim: Option<SimTime> = None;
    let epoch = SimTime::from_secs(5);
    let mut lat_sum = 0.0;
    let mut lat_n = 0u64;
    let epochs = minutes * 12;
    for e in 1..=epochs {
        let now = SimTime::from_micros(e * epoch.as_micros());
        let rec = app.run_epoch(now, epoch);
        let perf = rec.mean();
        naive_baseline.insert(now, perf); // no page-in filter!
        recent.insert(now, perf);
        let drop = match (naive_baseline.quantile(0.99), recent.quantile(0.99)) {
            (Some(b), Some(r)) => r > b * (1.0 + cfg.p99_threshold),
            _ => false,
        };
        let gated =
            last_reclaim.is_some_and(|t| now.saturating_sub(t) < cfg.cooling_period);
        if !drop && !gated {
            let rss = app.memory.rss_pages() as u64 * app.memory.page_bytes();
            let new_limit = limit.min(rss.max(page)).saturating_sub(cfg.chunk_bytes);
            if new_limit < rss {
                last_reclaim = Some(now);
            }
            app.memory.set_cgroup_limit(new_limit, now);
            limit = new_limit;
        }
        if e > epochs / 2 {
            lat_sum += perf;
            lat_n += 1;
        }
    }
    let h_naive = app.memory.shape().harvestable as f64 / GIB as f64;
    let naive_lat = lat_sum / lat_n as f64;

    let mut t = Table::new(vec![
        "baseline estimator",
        "harvested (GB)",
        "steady perf drop",
    ]);
    t.row(vec![
        "page-in filtered (Algorithm 1)".to_string(),
        format!("{h_proper:.2}"),
        pct((steady / base - 1.0).max(0.0)),
    ]);
    t.row(vec![
        "naive (all samples)".to_string(),
        format!("{h_naive:.2}"),
        pct((naive_lat / base - 1.0).max(0.0)),
    ]);
    vec![t]
}

/// Placement ablation: zero out the predicted-availability weight and
/// compare early-revocation rates in the trace replay.
pub fn ablation_placement(quick: bool) -> Vec<Table> {
    let steps = if quick { 80 } else { 288 };
    let n_p = if quick { 25 } else { 100 };
    let n_c = if quick { 50 } else { 200 };

    let with = replay(ReplayConfig {
        n_producers: n_p,
        n_consumers: n_c,
        steps,
        ..Default::default()
    });
    let without = replay(ReplayConfig {
        n_producers: n_p,
        n_consumers: n_c,
        steps,
        ignore_availability_prediction: true,
        ..Default::default()
    });
    let mut t = Table::new(vec![
        "placement",
        "slabs granted",
        "revoked before expiry",
        "utilization gain",
    ]);
    t.row(vec![
        "with availability forecast".to_string(),
        format!("{}", with.slabs_granted),
        pct(with.revoked_fraction),
        pct(with.memtrade_utilization - with.base_utilization),
    ]);
    t.row(vec![
        "forecast ignored".to_string(),
        format!("{}", without.slabs_granted),
        pct(without.revoked_fraction),
        pct(without.memtrade_utilization - without.base_utilization),
    ]);
    vec![t]
}

/// Fig 14 (appendix): memory composition over time for all six apps.
pub fn fig14(quick: bool) -> Vec<Table> {
    let mut out = Vec::new();
    for kind in AppKind::ALL {
        let page = if quick { 16 * MIB } else { 4 * MIB };
        let mut app = AppRunner::new(
            AppModel::preset(kind),
            page,
            SwapDevice::Ssd,
            Some(SimTime::from_mins(5)),
            13,
        );
        app.ops_cap_per_epoch = if quick { 200 } else { 800 };
        let mut p = Producer::new(ProducerId(1), app, HarvesterConfig::default(), 64 * MIB);
        let mut t =
            Table::new(vec!["t (min)", "RSS", "Silo", "harvested(disk)", "unallocated"]);
        let minutes = if quick { 30 } else { 90 };
        let epoch = SimTime::from_secs(5);
        for e in 1..=(minutes * 12) {
            p.tick(SimTime::from_micros(e * epoch.as_micros()), epoch);
            if e % (10 * 12) == 0 {
                let s = p.app.memory.shape();
                t.row(vec![
                    format!("{}", e / 12),
                    gb(s.rss),
                    gb(s.silo),
                    gb(s.swapped),
                    gb(s.unallocated),
                ]);
            }
        }
        println!("Fig 14 ({}):", kind.name());
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn silo_ablation_shows_benefit() {
        let t = ablation_silo(true);
        assert_eq!(t[0].csv().lines().count(), 4);
    }

    #[test]
    fn placement_ablation_runs() {
        let t = ablation_placement(true);
        let csv = t[0].csv();
        assert!(csv.lines().count() == 3);
    }
}

//! Harvester experiments: Fig 2b, Fig 3, Table 1, Fig 6, Fig 7, Fig 8,
//! Fig 9 (paper §7.1).

use crate::core::config::HarvesterConfig;
use crate::core::{SimTime, GIB, MIB};
use crate::mem::SwapDevice;
use crate::util::fmt::{gb, ms, pct, Table};
use crate::producer::Producer;
use crate::workload::apps::{AppKind, AppModel, AppRunner};
use crate::core::ProducerId;

fn page_bytes(quick: bool) -> u64 {
    if quick {
        16 * MIB
    } else {
        4 * MIB
    }
}

fn runner(kind: AppKind, device: SwapDevice, silo: bool, quick: bool, seed: u64) -> AppRunner {
    let model = AppModel::preset(kind);
    let mut r = AppRunner::new(
        model,
        page_bytes(quick),
        device,
        silo.then(|| SimTime::from_mins(5)),
        seed,
    );
    r.ops_cap_per_epoch = if quick { 300 } else { 1500 };
    r
}

/// Measure mean latency over `epochs` epochs of `dur` after harvesting a
/// static amount via the cgroup limit (the Fig 3/6 protocol).
fn static_harvest_latency(
    kind: AppKind,
    harvest_bytes: u64,
    silo: bool,
    quick: bool,
) -> (f64, f64) {
    let mut r = runner(kind, SwapDevice::Ssd, silo, quick, 7);
    let baseline = r.baseline_latency_us();
    let keep = r.model.footprint_bytes.saturating_sub(harvest_bytes);
    r.memory.set_cgroup_limit(keep, SimTime::ZERO);
    let epochs = if quick { 12 } else { 40 };
    let mut mean = baseline;
    for e in 1..=epochs {
        let now = SimTime::from_secs(e * 360); // past cooling each epoch
        let rec = r.run_epoch(now, SimTime::from_secs(5));
        mean = rec.mean();
    }
    (baseline, mean)
}

/// Fig 2b: idle application memory and how quickly it is reused. For
/// each producer app we report the idle share of its footprint, the
/// probability an idle-region page stays untouched for >= 1 hour (the
/// harvestable mass), and the median time until an idle page is reused.
pub fn fig2b(_quick: bool) -> Vec<Table> {
    let mut t = Table::new(vec![
        "app",
        "idle share of footprint",
        "idle GB",
        "P(idle page untouched >= 1h)",
        "median idle-page reuse time",
    ]);
    for kind in AppKind::ALL {
        let model = AppModel::preset(kind);
        let page = 4.0 * MIB as f64;
        let idle_pages = (model.footprint_bytes as f64 * model.idle_fraction() / page).max(1.0);
        // Per-op probability a *specific* idle page is touched.
        let p_touch_per_op = model.idle_access_prob
            * model.pages_per_op as f64
            / idle_pages;
        let ops_per_hour = model.ops_per_sec * 3600.0;
        let p_untouched_1h = (1.0 - p_touch_per_op).powf(ops_per_hour);
        // Geometric median in ops -> seconds.
        let median_ops = if p_touch_per_op > 0.0 {
            (0.5f64.ln() / (1.0 - p_touch_per_op).ln()).max(1.0)
        } else {
            f64::INFINITY
        };
        let median_secs = median_ops / model.ops_per_sec;
        let median_str = if median_secs.is_finite() {
            if median_secs > 3600.0 {
                format!("{:.1} h", median_secs / 3600.0)
            } else {
                format!("{:.1} min", median_secs / 60.0)
            }
        } else {
            "never".to_string()
        };
        t.row(vec![
            model.kind.name().to_string(),
            pct(model.idle_fraction()),
            format!(
                "{:.1}",
                model.footprint_bytes as f64 * model.idle_fraction() / GIB as f64
            ),
            pct(p_untouched_1h),
            median_str,
        ]);
    }
    vec![t]
}

/// Fig 3: performance drop vs harvested memory, no Silo (the cliff).
pub fn fig3(quick: bool) -> Vec<Table> {
    let mut out = Vec::new();
    for kind in [AppKind::Redis, AppKind::Xgboost] {
        let model = AppModel::preset(kind);
        let mut t = Table::new(vec![
            "harvested",
            "of footprint",
            "baseline",
            "mean latency",
            "drop",
        ]);
        let steps = if quick { 5 } else { 9 };
        for i in 0..=steps {
            let frac = i as f64 / steps as f64 * 0.9;
            let harvest = (model.footprint_bytes as f64 * frac) as u64;
            let (base, mean) = static_harvest_latency(kind, harvest, false, quick);
            t.row(vec![
                gb(harvest),
                pct(frac),
                ms(base),
                ms(mean),
                pct((mean / base - 1.0).max(0.0)),
            ]);
        }
        println!("Fig 3 ({}):", model.kind.name());
        out.push(t);
    }
    out
}

/// Fig 6: same sweep with and without Silo — Silo flattens the cliff.
pub fn fig6(quick: bool) -> Vec<Table> {
    let mut out = Vec::new();
    for kind in [AppKind::Redis, AppKind::Xgboost] {
        let model = AppModel::preset(kind);
        let mut t = Table::new(vec!["harvested", "drop w/o Silo", "drop w/ Silo"]);
        let steps = if quick { 4 } else { 8 };
        for i in 1..=steps {
            let frac = i as f64 / steps as f64 * 0.8;
            let harvest = (model.footprint_bytes as f64 * frac) as u64;
            let (base, without) = static_harvest_latency(kind, harvest, false, quick);
            let (_, with) = static_harvest_latency(kind, harvest, true, quick);
            t.row(vec![
                gb(harvest),
                pct((without / base - 1.0).max(0.0)),
                pct((with / base - 1.0).max(0.0)),
            ]);
        }
        println!("Fig 6 ({}):", model.kind.name());
        out.push(t);
    }
    out
}

/// Run the full adaptive harvester against one app; returns the producer
/// plus (baseline, final) mean latency.
fn adaptive_run(
    kind: AppKind,
    quick: bool,
    cfg: HarvesterConfig,
    minutes: u64,
) -> (Producer, f64, f64) {
    let app = runner(kind, SwapDevice::Ssd, true, quick, 11);
    let baseline = app.baseline_latency_us();
    let mut p = Producer::new(ProducerId(1), app, cfg, 64 * MIB);
    let epoch = SimTime::from_secs(5);
    let epochs = minutes * 60 / 5;
    let mut last = baseline;
    let mut sum = 0.0;
    let mut n = 0u64;
    for e in 1..=epochs {
        last = p.tick(SimTime::from_micros(e * epoch.as_micros()), epoch);
        if e > epochs / 2 {
            sum += last;
            n += 1;
        }
    }
    let steady = if n > 0 { sum / n as f64 } else { last };
    (p, baseline, steady)
}

/// Table 1: per-app harvested totals (idle + unallocated), % of app
/// memory harvested, and performance loss under the adaptive harvester.
pub fn table1(quick: bool) -> Vec<Table> {
    let mut t = Table::new(vec![
        "app",
        "VM size",
        "footprint",
        "total harvested",
        "idle harvested %",
        "workload harvested %",
        "perf loss",
    ]);
    let minutes = if quick { 30 } else { 120 };
    for kind in AppKind::ALL {
        let (p, baseline, steady) = adaptive_run(kind, quick, HarvesterConfig::default(), minutes);
        let shape = p.app.memory.shape();
        let model = &p.app.model;
        let total = shape.harvestable;
        // Memory truly extracted from the application = pages cooled out
        // to disk (Silo residents are still buffered in RAM).
        let from_app = shape.swapped;
        let idle_share =
            if total > 0 { (from_app as f64 / total as f64).min(1.0) } else { 0.0 };
        let workload_share = from_app as f64 / model.footprint_bytes as f64;
        let loss = (steady / baseline - 1.0).max(0.0);
        t.row(vec![
            model.kind.name().to_string(),
            gb(model.vm_bytes),
            gb(model.footprint_bytes),
            gb(total),
            pct(idle_share),
            pct(workload_share),
            pct(loss),
        ]);
    }
    vec![t]
}

/// Fig 7: memory composition over time (memcached + XGBoost).
pub fn fig7(quick: bool) -> Vec<Table> {
    let mut out = Vec::new();
    for kind in [AppKind::Memcached, AppKind::Xgboost] {
        let app = runner(kind, SwapDevice::Ssd, true, quick, 13);
        let mut p = Producer::new(ProducerId(1), app, HarvesterConfig::default(), 64 * MIB);
        let mut t = Table::new(vec!["t (min)", "RSS", "Silo", "harvested(disk)", "unallocated"]);
        let minutes = if quick { 40 } else { 120 };
        let epoch = SimTime::from_secs(5);
        for e in 1..=(minutes * 12) {
            p.tick(SimTime::from_micros(e * epoch.as_micros()), epoch);
            if e % (5 * 12) == 0 {
                let s = p.app.memory.shape();
                t.row(vec![
                    format!("{}", e / 12),
                    gb(s.rss),
                    gb(s.silo),
                    gb(s.swapped),
                    gb(s.unallocated),
                ]);
            }
        }
        println!("Fig 7 ({}):", kind.name());
        out.push(t);
    }
    out
}

/// Fig 8: workload burst (Zipf -> uniform) recovery across mitigations.
pub fn fig8(quick: bool) -> Vec<Table> {
    let mut t = Table::new(vec![
        "mitigation",
        "pre-burst latency",
        "burst peak",
        "recovery (s)",
        "post latency",
    ]);
    let cases: Vec<(&str, SwapDevice, bool)> = vec![
        ("no prefetch (SSD)", SwapDevice::Ssd, false),
        ("prefetch (SSD)", SwapDevice::Ssd, true),
        ("no prefetch (HDD)", SwapDevice::Hdd, false),
        ("prefetch (HDD)", SwapDevice::Hdd, true),
        ("zram (compressed RAM)", SwapDevice::Zram, true),
    ];
    for (name, device, prefetch) in cases {
        let model = AppModel::preset(AppKind::Redis);
        let mut app = AppRunner::new(
            model,
            page_bytes(quick),
            device,
            Some(SimTime::from_mins(5)),
            29,
        );
        app.ops_cap_per_epoch = if quick { 200 } else { 800 };
        let mut cfg = HarvesterConfig::default();
        if !prefetch {
            cfg.severe_epochs = u32::MAX; // disable prefetch entirely
        }
        let mut p = Producer::new(ProducerId(1), app, cfg, 64 * MIB);
        // Pre-harvest deep into the warm region (the paper runs for an
        // hour before the burst, with substantial memory already leased).
        let keep = (p.app.model.footprint_bytes as f64 * 0.45) as u64;
        p.app.memory.set_cgroup_limit(keep, SimTime::ZERO);
        let epoch = SimTime::from_secs(5);
        let warm_epochs = if quick { 240 } else { 720 };
        let mut pre = 0.0;
        for e in 1..=warm_epochs {
            pre = p.tick(SimTime::from_micros(e * epoch.as_micros()), epoch);
        }
        // Burst: shift to uniform (touches cold/idle pages).
        p.app.set_distribution_uniform();
        let mut peak = pre;
        let mut recovery_epochs = 0u64;
        let mut post = pre;
        let total = if quick { 240 } else { 720 };
        let mut recovered = false;
        for e in (warm_epochs + 1)..=(warm_epochs + total) {
            post = p.tick(SimTime::from_micros(e * epoch.as_micros()), epoch);
            peak = peak.max(post);
            if !recovered {
                recovery_epochs += 1;
                if post < pre * 1.10 {
                    recovered = true;
                }
            }
        }
        t.row(vec![
            name.to_string(),
            ms(pre),
            ms(peak),
            format!("{}", recovery_epochs * 5),
            ms(post),
        ]);
    }
    vec![t]
}

/// Fig 9: sensitivity of harvested memory + perf to each knob.
pub fn fig9(quick: bool) -> Vec<Table> {
    let minutes = if quick { 20 } else { 60 };
    let mut out = Vec::new();

    let run = |cfg: HarvesterConfig| -> (f64, f64) {
        let (p, baseline, steady) = adaptive_run(AppKind::Redis, quick, cfg, minutes);
        let harvested = p.app.memory.shape().harvestable as f64 / GIB as f64;
        (harvested, (steady / baseline - 1.0).max(0.0))
    };

    let mut t = Table::new(vec!["CoolingPeriod", "harvested (GB)", "perf drop"]);
    for mins in [1u64, 5, 15] {
        let mut cfg = HarvesterConfig::default();
        cfg.cooling_period = SimTime::from_mins(mins);
        let (h, d) = run(cfg);
        t.row(vec![format!("{mins} min"), format!("{h:.2}"), pct(d)]);
    }
    out.push(t);

    let mut t = Table::new(vec!["ChunkSize", "harvested (GB)", "perf drop"]);
    for mb in [16u64, 64, 256] {
        let mut cfg = HarvesterConfig::default();
        cfg.chunk_bytes = mb * MIB;
        let (h, d) = run(cfg);
        t.row(vec![format!("{mb} MB"), format!("{h:.2}"), pct(d)]);
    }
    out.push(t);

    let mut t = Table::new(vec!["P99Threshold", "harvested (GB)", "perf drop"]);
    for pth in [0.005, 0.01, 0.05] {
        let mut cfg = HarvesterConfig::default();
        cfg.p99_threshold = pth;
        let (h, d) = run(cfg);
        t.row(vec![pct(pth), format!("{h:.2}"), pct(d)]);
    }
    out.push(t);

    let mut t = Table::new(vec!["WindowSize", "harvested (GB)", "perf drop"]);
    for hours in [1u64, 6, 12] {
        let mut cfg = HarvesterConfig::default();
        cfg.window_size = SimTime::from_hours(hours);
        let (h, d) = run(cfg);
        t.row(vec![format!("{hours} h"), format!("{h:.2}"), pct(d)]);
    }
    out.push(t);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shows_cliff() {
        let tables = fig3(true);
        assert_eq!(tables.len(), 2);
        // Last row (deep harvest) must show a bigger drop than the first.
        let csv = tables[0].csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert!(lines.len() >= 5);
    }

    #[test]
    fn table1_covers_all_apps() {
        let tables = table1(true);
        let csv = tables[0].csv();
        for kind in AppKind::ALL {
            assert!(csv.contains(kind.name()), "{} missing", kind.name());
        }
    }

    #[test]
    fn fig9_produces_all_four_sweeps() {
        let tables = fig9(true);
        assert_eq!(tables.len(), 4);
    }
}

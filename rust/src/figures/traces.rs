//! Trace-analysis figures: Fig 1 (cluster utilization CDFs) and Fig 2a
//! (availability durations of unallocated memory).

use crate::util::fmt::{pct, Table};
use crate::workload::cluster_trace::{ClusterTrace, MachineClass};

fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((q * sorted.len() as f64) as usize).min(sorted.len() - 1);
    sorted[idx]
}

/// Fig 1: memory/CPU/network utilization CDF summary per cluster class.
pub fn fig1(quick: bool) -> Vec<Table> {
    let (machines, steps) = if quick { (100, 288) } else { (500, 288 * 7) };
    let mut t = Table::new(vec![
        "cluster",
        "resource",
        "p10",
        "p50",
        "p90",
        "max",
        "mean idle",
    ]);
    for class in [MachineClass::Google, MachineClass::Alibaba, MachineClass::Snowflake] {
        let trace = ClusterTrace::generate(class, machines, steps, 288, 31);
        let series: [(&str, Vec<f64>); 3] = [
            ("memory", (0..steps).map(|s| trace.cluster_mem_util(s)).collect()),
            ("cpu", (0..steps).map(|s| trace.cluster_cpu_util(s)).collect()),
            ("network", (0..steps).map(|s| trace.cluster_net_util(s)).collect()),
        ];
        for (name, mut xs) in series {
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mean: f64 = xs.iter().sum::<f64>() / xs.len() as f64;
            t.row(vec![
                format!("{class:?}"),
                name.to_string(),
                pct(quantile(&xs, 0.10)),
                pct(quantile(&xs, 0.50)),
                pct(quantile(&xs, 0.90)),
                pct(*xs.last().unwrap()),
                pct(1.0 - mean),
            ]);
        }
    }
    vec![t]
}

/// Fig 2a: how long unallocated memory stays available.
pub fn fig2a(quick: bool) -> Vec<Table> {
    let (machines, steps) = if quick { (100, 288 * 2) } else { (500, 288 * 7) };
    let trace = ClusterTrace::generate(MachineClass::Google, machines, steps, 288, 33);
    let mut t = Table::new(vec![
        "unallocated >=",
        "availability runs",
        ">= 1 hour",
        ">= 6 hours",
        ">= 1 day",
    ]);
    for frac in [0.1, 0.2, 0.4] {
        let durs = trace.availability_durations(frac);
        let total_mass: f64 = durs.iter().map(|&d| d as f64).sum();
        let mass_ge = |steps_min: usize| -> f64 {
            durs.iter().filter(|&&d| d >= steps_min).map(|&d| d as f64).sum::<f64>()
                / total_mass.max(1.0)
        };
        t.row(vec![
            pct(frac),
            format!("{}", durs.len()),
            pct(mass_ge(12)),
            pct(mass_ge(72)),
            pct(mass_ge(288)),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_has_nine_rows() {
        let tables = fig1(true);
        assert_eq!(tables[0].csv().lines().count(), 10); // header + 9
    }

    #[test]
    fn fig2a_availability_mostly_long() {
        let tables = fig2a(true);
        let csv = tables[0].csv();
        // The >=1h column for the 10% threshold should be high (paper: 99%).
        let row = csv.lines().nth(1).unwrap();
        let ge_1h: f64 = row
            .split(',')
            .nth(2)
            .unwrap()
            .trim_end_matches('%')
            .parse()
            .unwrap();
        assert!(ge_1h > 80.0, "availability mass {ge_1h}%");
    }
}

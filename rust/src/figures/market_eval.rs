//! Market experiments: Fig 12 (pricing strategies), Fig 13 (temporal
//! dynamics with trace-driven supply), Fig 15 (MRC library).

use crate::broker::pricing::PricingStrategy;
use crate::core::Money;
use crate::util::fmt::{pct, Table};
use crate::sim::market::{MarketSim, MarketSimConfig, MarketStep};
use crate::workload::cluster_trace::{ClusterTrace, MachineClass};
use crate::workload::memcachier::MrcLibrary;
use crate::workload::spot::SpotPriceSeries;

fn strategies() -> [(&'static str, PricingStrategy); 3] {
    [
        ("fixed (1/4 spot)", PricingStrategy::FixedFraction),
        ("max volume", PricingStrategy::MaxVolume),
        ("max revenue", PricingStrategy::MaxRevenue),
    ]
}

fn run_strategy(
    strategy: PricingStrategy,
    n_consumers: usize,
    steps: usize,
    supply_gb: impl Fn(usize) -> f64,
    spot: &SpotPriceSeries,
    eviction_probability: f64,
) -> (Vec<MarketStep>, MarketSim) {
    let lib = MrcLibrary::paper_population(7);
    let cfg = MarketSimConfig {
        n_consumers,
        strategy,
        seed: 23,
        max_slabs: 64,
        eviction_probability,
    };
    let mut sim = MarketSim::new(cfg, &lib, Money::from_dollars(0.00001));
    let mut out = Vec::with_capacity(steps);
    for t in 0..steps {
        out.push(sim.step(supply_gb(t), spot, t));
    }
    (out, sim)
}

/// Fig 12: strategy comparison at fixed supply.
pub fn fig12(quick: bool) -> Vec<Table> {
    let n = if quick { 1_000 } else { 10_000 };
    let steps = if quick { 60 } else { 300 };
    let spot = SpotPriceSeries::r3_large(steps, 41);
    let mut t = Table::new(vec![
        "strategy",
        "mean price ($/slab·h)",
        "mean traded slabs",
        "total revenue ($)",
        "rel. hit-ratio gain",
        "utilization",
    ]);
    for (name, strategy) in strategies() {
        let supply = (n as f64) * 0.5; // GB: scarce enough to matter
        let (step_rows, _) =
            run_strategy(strategy, n, steps, |_| supply, &spot, 0.0);
        let half = &step_rows[steps / 2..]; // steady state
        let mean = |f: &dyn Fn(&MarketStep) -> f64| {
            half.iter().map(|s| f(s)).sum::<f64>() / half.len() as f64
        };
        t.row(vec![
            name.to_string(),
            format!("{:.7}", mean(&|s: &MarketStep| s.price_per_slab_hour)),
            format!("{:.0}", mean(&|s: &MarketStep| s.traded_slabs)),
            format!("{:.2}", step_rows.iter().map(|s| s.revenue).sum::<f64>()),
            pct(mean(&|s: &MarketStep| s.rel_hit_improvement)),
            pct(mean(&|s: &MarketStep| s.utilization)),
        ]);
    }
    vec![t]
}

/// Fig 13: temporal market dynamics with Google-trace supply and the
/// spot price series; includes the §7.4 headline numbers.
pub fn fig13(quick: bool) -> Vec<Table> {
    let n = if quick { 1_000 } else { 10_000 };
    let steps = if quick { 120 } else { 576 };
    let spot = SpotPriceSeries::r3_large(steps, 43);
    // Supply: idle memory of a Google-trace cell, 5 GB per unit (paper).
    let trace = ClusterTrace::generate(MachineClass::Google, 200, steps, 288, 45);
    let supply_series: Vec<f64> = (0..steps)
        .map(|t| {
            let idle: f64 = trace
                .machines
                .iter()
                .map(|m| (1.0 - m.mem[t]).max(0.0))
                .sum();
            idle * 5.0 // "one Google unit represents 5 GB"
        })
        .collect();

    let mut dynamics = Table::new(vec![
        "strategy",
        "mean price",
        "price vs fixed",
        "total revenue",
        "mean utilization",
        "cost saving vs spot",
    ]);
    let mut fixed_price = 0.0;
    for (name, strategy) in strategies() {
        let supply = supply_series.clone();
        let (rows, _) =
            run_strategy(strategy, n, steps, move |t| supply[t], &spot, 0.0);
        let mean_price =
            rows.iter().map(|s| s.price_per_slab_hour).sum::<f64>() / rows.len() as f64;
        if strategy == PricingStrategy::FixedFraction {
            fixed_price = mean_price;
        }
        dynamics.row(vec![
            name.to_string(),
            format!("{mean_price:.7}"),
            format!("{:.2}x", mean_price / fixed_price.max(1e-12)),
            format!("{:.2}", rows.iter().map(|s| s.revenue).sum::<f64>()),
            pct(rows.iter().map(|s| s.utilization).sum::<f64>() / rows.len() as f64),
            pct(rows.iter().map(|s| s.cost_saving_vs_spot).sum::<f64>() / rows.len() as f64),
        ]);
    }

    // §7.4 eviction-probability scenario: revenue drop at 10% eviction.
    let mut evict = Table::new(vec![
        "strategy",
        "revenue (p_evict=0)",
        "revenue (p_evict=10%)",
        "drop",
    ]);
    for (name, strategy) in
        [("max volume", PricingStrategy::MaxVolume), ("max revenue", PricingStrategy::MaxRevenue)]
    {
        let supply = supply_series.clone();
        let (sure, _) =
            run_strategy(strategy, n, steps, {
                let supply = supply.clone();
                move |t| supply[t]
            }, &spot, 0.0);
        let (risky, _) =
            run_strategy(strategy, n, steps, move |t| supply[t], &spot, 0.10);
        let r0: f64 = sure.iter().map(|s| s.revenue).sum();
        let r1: f64 = risky.iter().map(|s| s.revenue).sum();
        evict.row(vec![
            name.to_string(),
            format!("{r0:.2}"),
            format!("{r1:.2}"),
            pct((1.0 - r1 / r0.max(1e-12)).max(0.0)),
        ]);
    }
    vec![dynamics, evict]
}

/// Fig 15: the synthetic MemCachier MRC library (36 apps).
pub fn fig15() -> Vec<Table> {
    let lib = MrcLibrary::paper_population(1);
    let mut t = Table::new(vec![
        "app",
        "req rate (/s)",
        "mr @ 0",
        "mr @ 1GB",
        "mr @ 4GB",
        "mr @ 8GB",
        "size for 80% optimal",
    ]);
    for mrc in &lib.mrcs {
        t.row(vec![
            format!("app{:02}", mrc.app_id),
            format!("{:.0}", mrc.req_rate),
            format!("{:.2}", mrc.at_bytes(0)),
            format!("{:.2}", mrc.at_bytes(1 << 30)),
            format!("{:.2}", mrc.at_bytes(4u64 << 30)),
            format!("{:.2}", mrc.at_bytes(8u64 << 30)),
            format!("{:.1} GB", mrc.size_for_relative_hit_ratio(0.8) as f64 / (1u64 << 30) as f64),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_compares_three_strategies() {
        let t = fig12(true);
        assert_eq!(t[0].csv().lines().count(), 4);
    }

    #[test]
    fn fig15_has_36_apps() {
        let t = fig15();
        assert_eq!(t[0].csv().lines().count(), 37);
    }

    #[test]
    fn fig13_eviction_reduces_revenue() {
        let tables = fig13(true);
        let csv = tables[1].csv();
        for line in csv.lines().skip(1) {
            let r0: f64 = line.split(',').nth(1).unwrap().parse().unwrap();
            let r1: f64 = line.split(',').nth(2).unwrap().parse().unwrap();
            assert!(r1 <= r0 * 1.02, "eviction raised revenue: {line}");
        }
    }
}

//! The experiment harness: one entry point per table/figure in the
//! paper's evaluation (§7). `memtrade figure <id>` regenerates the data
//! behind that figure and prints it as a markdown table — the printed
//! output is the record (DESIGN.md §Experiment index).
//!
//! | id        | paper result                                     |
//! |-----------|--------------------------------------------------|
//! | fig1      | cluster memory/CPU/net utilization CDFs          |
//! | fig2a     | unallocated-memory availability durations        |
//! | fig2b     | idle application memory reuse times              |
//! | fig3      | perf drop vs harvested memory (no Silo)          |
//! | table1    | harvested totals + perf loss, 6 producer apps    |
//! | fig6      | perf drop vs harvested, with vs without Silo     |
//! | fig7      | VM memory composition over time                  |
//! | fig8      | burst recovery: none / SSD / HDD / zram prefetch |
//! | fig9      | harvester sensitivity sweeps                     |
//! | fig10     | broker placement + cluster-wide utilization      |
//! | predictor | §7.2 ARIMA accuracy + early revocations          |
//! | fig11     | consumer latency vs remote-% across modes        |
//! | crypto    | §7.3 encryption/integrity overheads              |
//! | table2    | cluster deployment consumer/producer latencies   |
//! | fig12     | pricing strategies comparison                    |
//! | fig13     | temporal market dynamics                         |
//! | fig15     | 36 MemCachier-style MRCs                         |

pub mod ablations;
pub mod broker_eval;
pub mod consumer_eval;
pub mod harvesting;
pub mod market_eval;
pub mod traces;

use crate::util::fmt::Table;

/// All known experiment ids.
pub const ALL: &[&str] = &[
    "fig1", "fig2a", "fig2b", "fig3", "table1", "fig6", "fig7", "fig8", "fig9", "fig10",
    "predictor", "fig11", "crypto", "table2", "fig12", "fig13", "fig14", "fig15",
    "ablation_silo", "ablation_baseline", "ablation_placement",
];

/// Run one experiment by id, printing its table(s). `quick` shrinks the
/// workload so CI runs stay fast.
pub fn run(id: &str, quick: bool) -> Result<Vec<Table>, String> {
    let tables = match id {
        "fig1" => traces::fig1(quick),
        "fig2a" => traces::fig2a(quick),
        "fig2b" => harvesting::fig2b(quick),
        "fig3" => harvesting::fig3(quick),
        "table1" => harvesting::table1(quick),
        "fig6" => harvesting::fig6(quick),
        "fig7" => harvesting::fig7(quick),
        "fig8" => harvesting::fig8(quick),
        "fig9" => harvesting::fig9(quick),
        "fig10" => broker_eval::fig10(quick),
        "predictor" => broker_eval::predictor(quick),
        "fig11" => consumer_eval::fig11(quick),
        "crypto" => consumer_eval::crypto_overheads(quick),
        "table2" => consumer_eval::table2(quick),
        "fig12" => market_eval::fig12(quick),
        "fig13" => market_eval::fig13(quick),
        "fig14" => ablations::fig14(quick),
        "fig15" => market_eval::fig15(),
        "ablation_silo" => ablations::ablation_silo(quick),
        "ablation_baseline" => ablations::ablation_baseline(quick),
        "ablation_placement" => ablations::ablation_placement(quick),
        _ => return Err(format!("unknown figure id {id:?}; known: {ALL:?}")),
    };
    for t in &tables {
        t.print();
        println!();
    }
    Ok(tables)
}

//! Broker experiments: Fig 10 (placement + utilization) and the §7.2
//! availability-predictor accuracy numbers.

use crate::util::fmt::{pct, Table};
use crate::sim::replay::{run as replay, ReplayConfig};

/// Fig 10: requests satisfied vs producer DRAM, and cluster utilization.
pub fn fig10(quick: bool) -> Vec<Table> {
    let steps = if quick { 60 } else { 576 };
    let (n_producers, n_consumers) = if quick { (25, 50) } else { (100, 200) };
    let mut placement = Table::new(vec![
        "producer DRAM",
        "slabs requested",
        "slabs granted",
        "granted %",
        "requests (at least partly) satisfied",
    ]);
    let mut util = Table::new(vec!["producer DRAM", "base util", "with Memtrade", "gain"]);
    for producer_gb in [64.0, 128.0, 256.0, 512.0] {
        let r = replay(ReplayConfig {
            n_producers,
            n_consumers,
            producer_gb,
            steps,
            ..Default::default()
        });
        placement.row(vec![
            format!("{producer_gb:.0} GB"),
            format!("{}", r.slabs_requested),
            format!("{}", r.slabs_granted),
            pct(r.slabs_granted as f64 / r.slabs_requested.max(1) as f64),
            pct(r.requests_satisfied_eventually as f64 / r.requests.max(1) as f64),
        ]);
        util.row(vec![
            format!("{producer_gb:.0} GB"),
            pct(r.base_utilization),
            pct(r.memtrade_utilization),
            pct(r.memtrade_utilization - r.base_utilization),
        ]);
    }
    vec![placement, util]
}

/// §7.2: predictor accuracy + early-revocation rate.
pub fn predictor(quick: bool) -> Vec<Table> {
    let steps = if quick { 120 } else { 576 };
    let r = replay(ReplayConfig {
        n_producers: if quick { 25 } else { 100 },
        n_consumers: if quick { 50 } else { 200 },
        steps,
        ..Default::default()
    });
    let mut t = Table::new(vec!["metric", "paper", "ours"]);
    t.row(vec![
        "predictions over-estimating usage by >4%".to_string(),
        "9%".to_string(),
        pct(r.overprediction_fraction),
    ]);
    t.row(vec![
        "slabs revoked before lease expiry".to_string(),
        "4.59%".to_string(),
        pct(r.revoked_fraction),
    ]);
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_grants_increase_with_dram() {
        let tables = fig10(true);
        let csv = tables[0].csv();
        let granted: Vec<f64> = csv
            .lines()
            .skip(1)
            .map(|l| {
                l.split(',')
                    .nth(3)
                    .unwrap()
                    .trim_end_matches('%')
                    .parse()
                    .unwrap()
            })
            .collect();
        assert_eq!(granted.len(), 4);
        assert!(
            granted.last().unwrap() >= granted.first().unwrap(),
            "{granted:?}"
        );
    }
}

//! Consumer-side experiments: Fig 11 (application-level latencies across
//! remote fractions and security modes, including the swap interface),
//! the §7.3 crypto overheads (measured on the real AES/SHA code), and
//! Table 2 (cluster deployment).

use crate::consumer::swap_iface::SwapInterfaceModel;
use crate::core::{SimTime, GIB};
use crate::crypto::secure::Envelope;
use crate::util::fmt::{ms, pct, Table};
use crate::net::model::Locality;
use crate::sim::cluster::{ClusterSim, ClusterSimConfig, ConsumerMode};
use crate::workload::apps::AppKind;

fn sim_config(quick: bool, remote: f64, mode: ConsumerMode) -> ClusterSimConfig {
    ClusterSimConfig {
        n_producers: if quick { 4 } else { 12 },
        n_consumers: if quick { 3 } else { 8 },
        remote_fraction: remote,
        mode,
        n_keys: if quick { 5_000 } else { 40_000 },
        value_size: 1024,
        ops_per_epoch: if quick { 120 } else { 400 },
        page_bytes: if quick { 16 << 20 } else { 4 << 20 },
        seed: 51,
        ..Default::default()
    }
}

fn run_case(quick: bool, remote: f64, mode: ConsumerMode) -> (f64, f64) {
    let mut sim = ClusterSim::new(sim_config(quick, remote, mode));
    sim.bootstrap();
    sim.run(if quick { SimTime::from_mins(4) } else { SimTime::from_mins(15) });
    (sim.consumer_mean_latency(), sim.consumer_p99_latency())
}

/// Fig 11: consumer latency vs remote fraction across interfaces.
pub fn fig11(quick: bool) -> Vec<Table> {
    let mut avg = Table::new(vec![
        "remote %",
        "no Memtrade (SSD)",
        "secure KV",
        "integrity-only KV",
        "plain KV",
        "secure swap (model)",
    ]);
    let mut p99 = Table::new(vec![
        "remote %",
        "no Memtrade (SSD)",
        "secure KV",
        "integrity-only KV",
        "plain KV",
    ]);
    let swap_model = SwapInterfaceModel::default();
    for remote in [0.0, 0.10, 0.30, 0.50] {
        let (ssd_avg, ssd_p99) = run_case(quick, remote, ConsumerMode::NoMemtrade);
        let (sec_avg, sec_p99) = run_case(quick, remote, ConsumerMode::Secure);
        let (int_avg, int_p99) = run_case(quick, remote, ConsumerMode::IntegrityOnly);
        let (pl_avg, pl_p99) = run_case(quick, remote, ConsumerMode::Plain);
        // Swap interface: remote fault latency model applied to the same
        // remote-access fraction (paper: swap loses due to block layer).
        let swap_fault =
            swap_model.fault_latency(Locality::SameDatacenter, true).as_micros() as f64;
        let kv_fault = swap_model
            .kv_get_latency(Locality::SameDatacenter, 30, true)
            .as_micros() as f64;
        let swap_avg = if sec_avg > 0.0 {
            // Replace the KV remote component with the swap component.
            sec_avg + (swap_fault - kv_fault) * remote * 0.7
        } else {
            0.0
        };
        avg.row(vec![
            pct(remote),
            ms(ssd_avg),
            ms(sec_avg),
            ms(int_avg),
            ms(pl_avg),
            ms(swap_avg),
        ]);
        p99.row(vec![pct(remote), ms(ssd_p99), ms(sec_p99), ms(int_p99), ms(pl_p99)]);
    }
    println!("Fig 11a (average latency):");
    println!("Fig 11b (p99 latency): (second table)");
    vec![avg, p99]
}

/// §7.3 crypto overheads, measured on the real from-scratch AES/SHA.
pub fn crypto_overheads(quick: bool) -> Vec<Table> {
    let n = if quick { 2_000 } else { 20_000 };
    let value_size = 1024;
    let value = vec![0xA5u8; value_size];

    let mut t = Table::new(vec![
        "mode",
        "seal (µs/op)",
        "open (µs/op)",
        "producer-side space overhead",
        "consumer metadata bytes/KV",
    ]);
    for (name, key, integrity) in [
        ("plain", None, false),
        ("integrity-only", None, true),
        ("encrypt+integrity", Some([9u8; 16]), true),
    ] {
        let mut env = Envelope::with_iv_seed(key, integrity, 77);
        let start = std::time::Instant::now();
        let mut sealed = Vec::with_capacity(n);
        for _ in 0..n {
            sealed.push(env.seal(&value, 0));
        }
        let seal_us = start.elapsed().as_micros() as f64 / n as f64;
        let start = std::time::Instant::now();
        for s in &sealed {
            let _ = env.open(&s.value_p, &s.meta).unwrap();
        }
        let open_us = start.elapsed().as_micros() as f64 / n as f64;
        let overhead = sealed[0].value_p.len() as f64 / value_size as f64 - 1.0;
        let meta = crate::crypto::secure::SealedValue::metadata_bytes(key.is_some());
        t.row(vec![
            name.to_string(),
            format!("{seal_us:.2}"),
            format!("{open_us:.2}"),
            pct(overhead),
            format!("{meta}"),
        ]);
    }
    vec![t]
}

/// Table 2: the cluster deployment — consumer latencies with/without
/// Memtrade and producer latencies with/without the harvester.
pub fn table2(quick: bool) -> Vec<Table> {
    // Consumer side.
    let mut consumer = Table::new(vec![
        "consumer app",
        "avg latency w/o Memtrade",
        "avg latency w/ Memtrade",
        "improvement",
    ]);
    for remote in [0.10, 0.30, 0.50] {
        let (ssd, _) = run_case(quick, remote, ConsumerMode::NoMemtrade);
        let (sec, _) = run_case(quick, remote, ConsumerMode::Secure);
        consumer.row(vec![
            format!("Redis {}%", (remote * 100.0) as u32),
            ms(ssd),
            ms(sec),
            format!("{:.1}x", ssd / sec.max(1.0)),
        ]);
    }

    // Producer side: per-app latency with and without the harvester.
    let mut producer = Table::new(vec![
        "producer app",
        "avg latency w/o harvester",
        "avg latency w/ harvester",
        "degradation",
    ]);
    for kind in AppKind::ALL {
        use crate::core::config::HarvesterConfig;
        use crate::core::ProducerId;
        use crate::mem::SwapDevice;
        use crate::producer::Producer;
        use crate::workload::apps::{AppModel, AppRunner};
        let minutes: u64 = if quick { 20 } else { 60 };
        let model = AppModel::preset(kind);
        let page = if quick { 16 << 20 } else { 4 << 20 };
        // Without harvester: app runs untouched => baseline latency.
        let baseline = model.base_latency_us;
        // With harvester:
        let mut app = AppRunner::new(
            model.clone(),
            page,
            SwapDevice::Ssd,
            Some(SimTime::from_mins(5)),
            61,
        );
        app.ops_cap_per_epoch = if quick { 200 } else { 800 };
        let mut p = Producer::new(ProducerId(1), app, HarvesterConfig::default(), 64 << 20);
        let epoch = SimTime::from_secs(5);
        let mut sum = 0.0;
        let mut n = 0u64;
        let epochs = minutes * 12;
        for e in 1..=epochs {
            let lat = p.tick(SimTime::from_micros(e * epoch.as_micros()), epoch);
            if e > epochs / 2 {
                sum += lat;
                n += 1;
            }
        }
        let with = sum / n as f64;
        producer.row(vec![
            kind.name().to_string(),
            ms(baseline),
            ms(with),
            pct((with / baseline - 1.0).max(0.0)),
        ]);
    }
    println!("Table 2 (consumers, then producers):");
    vec![consumer, producer]
}

/// Cluster-wide memory footprint summary for the deploy example.
pub fn deploy_summary(sim: &ClusterSim) -> Table {
    let mut t = Table::new(vec!["metric", "value"]);
    let leased = sim.leased_bytes();
    let producer_mem: u64 = sim.producers.iter().map(|p| p.app.model.vm_bytes).sum();
    let harvestable: u64 =
        sim.producers.iter().map(|p| p.app.memory.shape().harvestable).sum();
    t.row(vec!["producers".to_string(), format!("{}", sim.producers.len())]);
    t.row(vec!["consumers".to_string(), format!("{}", sim.consumers.len())]);
    t.row(vec![
        "total producer memory".to_string(),
        format!("{:.1} GB", producer_mem as f64 / GIB as f64),
    ]);
    t.row(vec![
        "harvestable".to_string(),
        format!("{:.1} GB", harvestable as f64 / GIB as f64),
    ]);
    t.row(vec![
        "leased to consumers".to_string(),
        format!("{:.1} GB", leased as f64 / GIB as f64),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crypto_overheads_ordered() {
        let t = crypto_overheads(true);
        let csv = t[0].csv();
        let seal: Vec<f64> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(1).unwrap().parse().unwrap())
            .collect();
        // plain <= integrity <= encrypt+integrity
        assert!(seal[0] <= seal[1] + 0.5);
        assert!(seal[1] <= seal[2] + 0.5);
    }
}

//! Swap-device latency models (paper §7 testbed: Intel DC S3520 SSDs,
//! 7200 RPM SAS HDDs, and the zram compressed-RAM alternative of §4.1).

use crate::core::SimTime;

/// Backing device for swapped-out pages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwapDevice {
    /// SATA SSD: ~100 µs 4K random read, ~60 µs write.
    Ssd,
    /// 7200 RPM HDD: seek-bound, ~8 ms random read.
    Hdd,
    /// Compressed RAM disk (zram): ~10 µs decompress, but pages keep
    /// occupying ~`1/compression_ratio` of their size in memory.
    Zram,
}

impl SwapDevice {
    /// Latency to fault one page back in.
    pub fn read_latency(self) -> SimTime {
        match self {
            SwapDevice::Ssd => SimTime::from_micros(100),
            SwapDevice::Hdd => SimTime::from_micros(8_000),
            SwapDevice::Zram => SimTime::from_micros(10),
        }
    }

    /// Latency to write one page out (asynchronous in practice, but it
    /// consumes device bandwidth; we charge it to background work).
    pub fn write_latency(self) -> SimTime {
        match self {
            SwapDevice::Ssd => SimTime::from_micros(60),
            SwapDevice::Hdd => SimTime::from_micros(8_000),
            SwapDevice::Zram => SimTime::from_micros(15),
        }
    }

    /// Fraction of a swapped page that still occupies RAM (zram keeps
    /// compressed data resident; disks keep none).
    pub fn resident_fraction(self) -> f64 {
        match self {
            SwapDevice::Ssd | SwapDevice::Hdd => 0.0,
            SwapDevice::Zram => 0.4, // ~2.5x compression
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latencies_ordered() {
        assert!(SwapDevice::Zram.read_latency() < SwapDevice::Ssd.read_latency());
        assert!(SwapDevice::Ssd.read_latency() < SwapDevice::Hdd.read_latency());
    }

    #[test]
    fn zram_keeps_residency() {
        assert_eq!(SwapDevice::Ssd.resident_fraction(), 0.0);
        assert!(SwapDevice::Zram.resident_fraction() > 0.0);
    }
}

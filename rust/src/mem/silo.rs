//! Silo — the paper's in-memory victim cache for swapped-out pages
//! (§4.1).  Implemented in the real system as a frontswap backend kernel
//! module; here as the equivalent model: a FIFO of (entry time, page)
//! whose pages are
//!
//! * mapped back cheaply on access (preventing the performance cliff of a
//!   hot page reaching disk),
//! * evicted to the swap device once resident longer than the
//!   CoolingPeriod (making their memory truly harvestable),
//! * and prefetched back from disk (most-recently-swapped first) when the
//!   harvester detects a severe performance drop.

use crate::core::SimTime;
use std::collections::{HashMap, VecDeque};

/// Victim cache of swapped-out pages awaiting cooling.
pub struct Silo {
    /// FIFO in entry order: (entered_at, page). Stale entries (pages that
    /// were mapped back) are skipped lazily via the `members` check.
    queue: VecDeque<(SimTime, u32)>,
    /// page -> entry time for liveness/containment checks.
    members: HashMap<u32, SimTime>,
    cooling: SimTime,
    pub stats: SiloStats,
}

#[derive(Clone, Debug, Default)]
pub struct SiloStats {
    pub admitted: u64,
    pub mapped_back: u64,
    pub cooled_to_disk: u64,
}

impl crate::metrics::Observe for SiloStats {
    fn observe(&self, prefix: &str, out: &mut crate::metrics::MetricSet) {
        use crate::metrics::scoped;
        out.set_counter(scoped(prefix, "admitted"), self.admitted);
        out.set_counter(scoped(prefix, "mapped_back"), self.mapped_back);
        out.set_counter(scoped(prefix, "cooled_to_disk"), self.cooled_to_disk);
    }
}

impl Silo {
    pub fn new(cooling: SimTime) -> Self {
        Silo {
            queue: VecDeque::new(),
            members: HashMap::new(),
            cooling,
            stats: SiloStats::default(),
        }
    }

    pub fn cooling_period(&self) -> SimTime {
        self.cooling
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn contains(&self, page: u32) -> bool {
        self.members.contains_key(&page)
    }

    /// Frontswap store: a reclaimed page enters the victim cache.
    ///
    /// Idempotent: re-admitting a page that is already resident
    /// refreshes its entry time (its cooling clock restarts). The
    /// superseded queue record is skipped lazily by
    /// [`Self::drain_cooled`]'s entry-time check — re-admission is the
    /// very case that check documents, so it must not be asserted away
    /// (it used to panic debug builds).
    pub fn admit(&mut self, now: SimTime, page: u32) {
        self.queue.push_back((now, page));
        self.members.insert(page, now);
        self.stats.admitted += 1;
    }

    /// Frontswap load: an access maps the page back into the application
    /// address space. Returns true if the page was present.
    pub fn map_back(&mut self, page: u32) -> bool {
        if self.members.remove(&page).is_some() {
            self.stats.mapped_back += 1;
            true // stale queue entry skipped lazily during drain
        } else {
            false
        }
    }

    /// Drain pages whose residency exceeded the CoolingPeriod; they are
    /// written to the swap device by the caller. Returns the cooled pages.
    pub fn drain_cooled(&mut self, now: SimTime) -> Vec<u32> {
        let mut out = Vec::new();
        while let Some(&(t, page)) = self.queue.front() {
            // Lazily skip entries whose page was mapped back (or re-admitted
            // later: entry time mismatch).
            match self.members.get(&page) {
                Some(&entered) if entered == t => {
                    if now.saturating_sub(t) >= self.cooling {
                        self.queue.pop_front();
                        self.members.remove(&page);
                        self.stats.cooled_to_disk += 1;
                        out.push(page);
                    } else {
                        break;
                    }
                }
                _ => {
                    self.queue.pop_front();
                }
            }
        }
        out
    }

    /// All resident pages, oldest first (used when flushing Silo).
    pub fn drain_all(&mut self) -> Vec<u32> {
        let mut out = Vec::new();
        while let Some((t, page)) = self.queue.pop_front() {
            if self.members.get(&page) == Some(&t) {
                self.members.remove(&page);
                out.push(page);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_and_map_back() {
        let mut s = Silo::new(SimTime::from_mins(5));
        s.admit(SimTime::ZERO, 7);
        assert!(s.contains(7));
        assert!(s.map_back(7));
        assert!(!s.contains(7));
        assert!(!s.map_back(7));
        assert_eq!(s.stats.mapped_back, 1);
    }

    #[test]
    fn cooling_order_and_threshold() {
        let mut s = Silo::new(SimTime::from_secs(60));
        s.admit(SimTime::from_secs(0), 1);
        s.admit(SimTime::from_secs(30), 2);
        s.admit(SimTime::from_secs(50), 3);
        // At t=59 nothing has cooled.
        assert!(s.drain_cooled(SimTime::from_secs(59)).is_empty());
        // At t=60, page 1 cooled; at t=95, page 2.
        assert_eq!(s.drain_cooled(SimTime::from_secs(60)), vec![1]);
        assert_eq!(s.drain_cooled(SimTime::from_secs(95)), vec![2]);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn mapped_back_pages_do_not_cool() {
        let mut s = Silo::new(SimTime::from_secs(10));
        s.admit(SimTime::ZERO, 1);
        s.admit(SimTime::ZERO, 2);
        assert!(s.map_back(1));
        assert_eq!(s.drain_cooled(SimTime::from_secs(20)), vec![2]);
        assert!(s.is_empty());
        assert_eq!(s.stats.cooled_to_disk, 1);
    }

    #[test]
    fn readmission_uses_new_timestamp() {
        let mut s = Silo::new(SimTime::from_secs(10));
        s.admit(SimTime::ZERO, 1);
        assert!(s.map_back(1));
        s.admit(SimTime::from_secs(9), 1); // re-admitted just before old cooling
        assert!(s.drain_cooled(SimTime::from_secs(10)).is_empty());
        assert_eq!(s.drain_cooled(SimTime::from_secs(19)), vec![1]);
    }

    #[test]
    fn readmission_while_resident_is_idempotent() {
        // Regression: a legal re-admission (page still resident) used to
        // trip admit's debug_assert. It must instead restart the page's
        // cooling clock and leave exactly one live membership.
        let mut s = Silo::new(SimTime::from_secs(10));
        s.admit(SimTime::ZERO, 1);
        s.admit(SimTime::from_secs(6), 1); // re-admitted, never mapped back
        assert_eq!(s.len(), 1);
        // Old entry time (t=0) no longer cools the page at t=10...
        assert!(s.drain_cooled(SimTime::from_secs(10)).is_empty());
        assert!(s.contains(1));
        // ...the refreshed time (t=6) does at t=16.
        assert_eq!(s.drain_cooled(SimTime::from_secs(16)), vec![1]);
        assert!(s.is_empty());
        assert_eq!(s.stats.cooled_to_disk, 1);
    }

    #[test]
    fn readmission_after_cooling_starts_fresh() {
        let mut s = Silo::new(SimTime::from_secs(10));
        s.admit(SimTime::ZERO, 1);
        assert_eq!(s.drain_cooled(SimTime::from_secs(10)), vec![1]);
        // Back from disk and reclaimed again: a brand-new residency.
        s.admit(SimTime::from_secs(20), 1);
        assert!(s.contains(1));
        assert!(s.drain_cooled(SimTime::from_secs(29)).is_empty());
        assert_eq!(s.drain_cooled(SimTime::from_secs(30)), vec![1]);
        assert_eq!(s.stats.admitted, 2);
    }

    #[test]
    fn drain_all_flushes() {
        let mut s = Silo::new(SimTime::from_hours(1));
        for p in 0..10 {
            s.admit(SimTime::from_secs(p as u64), p);
        }
        s.map_back(3);
        let drained = s.drain_all();
        assert_eq!(drained.len(), 9);
        assert!(!drained.contains(&3));
        assert!(s.is_empty());
    }
}

//! Guest-VM memory model — the substrate that stands in for the paper's
//! Linux kernel machinery (cgroup memory limits, the Page Frame
//! Reclamation Algorithm, frontswap, and swap devices), plus **Silo**, the
//! paper's novel in-memory victim cache (§4.1).
//!
//! The model is page-granular: application memory is a set of logical
//! pages, each resident in memory, parked in Silo, or swapped out to a
//! device. A cgroup limit below the resident set triggers reclaim through
//! a sampled-LRU approximation of the PFRA — which, like the real PFRA,
//! sometimes picks warm pages (the imperfection Silo exists to absorb).
//! Reclaimed pages enter Silo via the frontswap hook; pages idle in Silo
//! longer than the CoolingPeriod are written to the swap device and their
//! memory becomes harvestable. Faults on swapped pages pay the device's
//! read latency; faults on Silo pages are cheap map-backs.

pub mod guest;
pub mod silo;
pub mod swap;

pub use guest::{AccessOutcome, GuestMemory, MemShape};
pub use silo::Silo;
pub use swap::SwapDevice;

//! The guest-VM page-level memory model: cgroup limit, sampled-LRU PFRA,
//! frontswap into Silo, swap device, prefetch, and the memory-composition
//! accounting behind the paper's Figures 3, 6, 7/14 and Table 1.

use crate::core::SimTime;
use crate::mem::silo::Silo;
use crate::mem::swap::SwapDevice;
use crate::util::rng::Rng;

/// Where a page currently lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum PageState {
    InMemory,
    InSilo,
    OnDisk,
}

/// Result of one page access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessOutcome {
    /// Resident — base cost only.
    Hit,
    /// Mapped back from Silo (minor-fault cost).
    SiloHit,
    /// Major fault from the swap device (promotion / swap-in).
    DiskFault,
}

impl AccessOutcome {
    pub fn is_fault(self) -> bool {
        !matches!(self, AccessOutcome::Hit)
    }
}

/// VM memory composition snapshot, in bytes (Fig 7/14 series).
#[derive(Clone, Copy, Debug, Default)]
pub struct MemShape {
    pub total: u64,
    pub rss: u64,
    pub silo: u64,
    pub swapped: u64,
    pub unallocated: u64,
    /// total - rss - silo - zram residue: what the manager may lease.
    pub harvestable: u64,
}

#[derive(Clone, Debug, Default)]
pub struct GuestStats {
    pub accesses: u64,
    pub silo_hits: u64,
    pub disk_faults: u64,
    /// Pages written to the swap device (cooled out of Silo).
    pub swap_outs: u64,
    /// Pages prefetched back by burst mitigation.
    pub prefetched: u64,
}

impl crate::metrics::Observe for GuestStats {
    fn observe(&self, prefix: &str, out: &mut crate::metrics::MetricSet) {
        use crate::metrics::scoped;
        out.set_counter(scoped(prefix, "accesses"), self.accesses);
        out.set_counter(scoped(prefix, "silo_hits"), self.silo_hits);
        out.set_counter(scoped(prefix, "disk_faults"), self.disk_faults);
        out.set_counter(scoped(prefix, "swap_outs"), self.swap_outs);
        out.set_counter(scoped(prefix, "prefetched"), self.prefetched);
    }
}

/// PFRA sampling width: how many resident pages the reclaimer inspects
/// per eviction. Small values make reclaim (realistically) imperfect.
const PFRA_SAMPLES: usize = 8;

/// Page-granular guest memory for one producer VM.
pub struct GuestMemory {
    page_bytes: u64,
    /// VM DRAM size in pages.
    total_pages: u32,
    /// Application footprint in pages (indices 0..app_pages).
    app_pages: u32,
    /// cgroup memory limit, in pages.
    limit_pages: u32,
    state: Vec<PageState>,
    /// Logical recency clock per page (bumped on access).
    last_access: Vec<u64>,
    clock: u64,
    /// Resident page ids, for O(1) sampled reclaim.
    resident: Vec<u32>,
    /// Position of page in `resident` (u32::MAX when absent).
    resident_idx: Vec<u32>,
    /// Pages on disk in swap-out order (for most-recent-first prefetch).
    disk_lifo: Vec<u32>,
    silo: Option<Silo>,
    device: SwapDevice,
    rng: Rng,
    pub stats: GuestStats,
}

const NOT_RESIDENT: u32 = u32::MAX;

impl GuestMemory {
    /// A VM with `total_bytes` DRAM running an app of `app_bytes`;
    /// `silo_cooling = None` disables Silo (pages swap straight to disk).
    pub fn new(
        total_bytes: u64,
        app_bytes: u64,
        page_bytes: u64,
        device: SwapDevice,
        silo_cooling: Option<SimTime>,
        seed: u64,
    ) -> Self {
        assert!(app_bytes <= total_bytes);
        let total_pages = (total_bytes / page_bytes) as u32;
        let app_pages = (app_bytes / page_bytes) as u32;
        let state = vec![PageState::InMemory; app_pages as usize];
        let last_access = vec![0u64; app_pages as usize];
        let resident: Vec<u32> = (0..app_pages).collect();
        let resident_idx: Vec<u32> = (0..app_pages).collect();
        GuestMemory {
            page_bytes,
            total_pages,
            app_pages,
            limit_pages: total_pages,
            state,
            last_access,
            clock: 0,
            resident,
            resident_idx,
            disk_lifo: Vec::new(),
            silo: silo_cooling.map(Silo::new),
            device,
            rng: Rng::new(seed),
            stats: GuestStats::default(),
        }
    }

    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }
    pub fn app_pages(&self) -> u32 {
        self.app_pages
    }
    pub fn rss_pages(&self) -> u32 {
        self.resident.len() as u32
    }
    pub fn silo_pages(&self) -> u32 {
        self.silo.as_ref().map_or(0, |s| s.len() as u32)
    }
    pub fn disk_pages(&self) -> u32 {
        self.state.iter().filter(|s| **s == PageState::OnDisk).count() as u32
    }
    pub fn device(&self) -> SwapDevice {
        self.device
    }

    /// Current memory composition (Fig 7/14).
    pub fn shape(&self) -> MemShape {
        let total = self.total_pages as u64 * self.page_bytes;
        let rss = self.resident.len() as u64 * self.page_bytes;
        let silo = self.silo_pages() as u64 * self.page_bytes;
        let swapped_pages = self.app_pages as u64 - self.resident.len() as u64
            - self.silo_pages() as u64;
        let swapped = swapped_pages * self.page_bytes;
        // zram keeps a compressed residue of swapped pages in RAM.
        let residue = (swapped as f64 * self.device.resident_fraction()) as u64;
        let unallocated = total - self.app_pages as u64 * self.page_bytes;
        let harvestable = total - rss - silo - residue;
        MemShape { total, rss, silo, swapped, unallocated, harvestable }
    }

    fn resident_push(&mut self, page: u32) {
        self.resident_idx[page as usize] = self.resident.len() as u32;
        self.resident.push(page);
        self.state[page as usize] = PageState::InMemory;
    }

    fn resident_remove(&mut self, page: u32) {
        let idx = self.resident_idx[page as usize];
        debug_assert_ne!(idx, NOT_RESIDENT);
        self.resident.swap_remove(idx as usize);
        if (idx as usize) < self.resident.len() {
            let moved = self.resident[idx as usize];
            self.resident_idx[moved as usize] = idx;
        }
        self.resident_idx[page as usize] = NOT_RESIDENT;
    }

    /// Access one page; returns the outcome (caller charges latency).
    pub fn access(&mut self, page: u32, now: SimTime) -> AccessOutcome {
        debug_assert!(page < self.app_pages);
        self.clock += 1;
        self.last_access[page as usize] = self.clock;
        self.stats.accesses += 1;
        match self.state[page as usize] {
            PageState::InMemory => AccessOutcome::Hit,
            PageState::InSilo => {
                let silo = self.silo.as_mut().expect("page marked InSilo without Silo");
                let present = silo.map_back(page);
                debug_assert!(present);
                self.resident_push(page);
                self.stats.silo_hits += 1;
                // Mapping back may push RSS above the cgroup limit again;
                // the PFRA will rebalance on the next reclaim pass.
                self.reclaim_to_limit(now);
                AccessOutcome::SiloHit
            }
            PageState::OnDisk => {
                // Major fault: swap in, promote.
                if let Some(pos) = self.disk_lifo.iter().rposition(|&p| p == page) {
                    self.disk_lifo.remove(pos);
                }
                self.resident_push(page);
                self.stats.disk_faults += 1;
                self.reclaim_to_limit(now);
                AccessOutcome::DiskFault
            }
        }
    }

    /// Set the cgroup limit (bytes); lowering it triggers PFRA reclaim.
    pub fn set_cgroup_limit(&mut self, bytes: u64, now: SimTime) {
        self.limit_pages = (bytes / self.page_bytes).min(self.total_pages as u64) as u32;
        self.reclaim_to_limit(now);
    }

    /// Remove any cgroup limit (recovery mode, Algorithm 1 line 6).
    pub fn disable_cgroup_limit(&mut self) {
        self.limit_pages = self.total_pages;
    }

    pub fn cgroup_limit_bytes(&self) -> u64 {
        self.limit_pages as u64 * self.page_bytes
    }

    /// PFRA: evict sampled-LRU resident pages until RSS <= limit.
    fn reclaim_to_limit(&mut self, now: SimTime) {
        while self.resident.len() as u32 > self.limit_pages {
            if self.resident.is_empty() {
                break;
            }
            let victim = self.pick_victim();
            self.resident_remove(victim);
            match &mut self.silo {
                Some(silo) => {
                    self.state[victim as usize] = PageState::InSilo;
                    silo.admit(now, victim);
                }
                None => {
                    self.state[victim as usize] = PageState::OnDisk;
                    self.disk_lifo.push(victim);
                    self.stats.swap_outs += 1;
                }
            }
        }
    }

    /// Sampled LRU: inspect PFRA_SAMPLES random resident pages, evict the
    /// coldest. Imperfect by construction — occasionally a warm page goes.
    fn pick_victim(&mut self) -> u32 {
        let n = self.resident.len();
        let mut best: Option<(u64, u32)> = None;
        for _ in 0..PFRA_SAMPLES.min(n) {
            let i = self.rng.below(n as u64) as usize;
            let page = self.resident[i];
            let age = self.last_access[page as usize];
            if best.map_or(true, |(a, _)| age < a) {
                best = Some((age, page));
            }
        }
        best.expect("non-empty resident set").1
    }

    /// Advance Silo cooling: pages resident past the CoolingPeriod are
    /// written to the swap device. Returns pages moved (device write cost
    /// is background work).
    pub fn tick(&mut self, now: SimTime) -> usize {
        let Some(silo) = &mut self.silo else { return 0 };
        let cooled = silo.drain_cooled(now);
        let n = cooled.len();
        for page in cooled {
            self.state[page as usize] = PageState::OnDisk;
            self.disk_lifo.push(page);
            self.stats.swap_outs += 1;
        }
        n
    }

    /// Burst mitigation (§4.1): prefetch up to `bytes` of the most
    /// recently swapped-out pages back into memory. Returns pages fetched;
    /// the caller charges `pages * device.read_latency()` as background
    /// I/O (it does not block the application).
    pub fn prefetch(&mut self, bytes: u64, now: SimTime) -> usize {
        let want = (bytes / self.page_bytes) as usize;
        let mut fetched = 0;
        while fetched < want {
            let Some(page) = self.disk_lifo.pop() else { break };
            debug_assert_eq!(self.state[page as usize], PageState::OnDisk);
            self.resident_push(page);
            self.clock += 1;
            self.last_access[page as usize] = self.clock;
            fetched += 1;
        }
        self.stats.prefetched += fetched as u64;
        // Respect the (possibly disabled) limit.
        self.reclaim_to_limit(now);
        fetched
    }

    /// Swapped-in page count — the "promotion rate" performance proxy.
    pub fn promotions(&self) -> u64 {
        self.stats.disk_faults
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PAGE: u64 = 4096;

    fn gm(total_mb: u64, app_mb: u64, silo: bool) -> GuestMemory {
        GuestMemory::new(
            total_mb << 20,
            app_mb << 20,
            PAGE,
            SwapDevice::Ssd,
            silo.then(|| SimTime::from_secs(60)),
            7,
        )
    }

    #[test]
    fn initial_shape() {
        let g = gm(64, 32, true);
        let s = g.shape();
        assert_eq!(s.total, 64 << 20);
        assert_eq!(s.rss, 32 << 20);
        assert_eq!(s.silo, 0);
        assert_eq!(s.swapped, 0);
        assert_eq!(s.unallocated, 32 << 20);
        assert_eq!(s.harvestable, 32 << 20);
    }

    #[test]
    fn lowering_limit_reclaims_into_silo() {
        let mut g = gm(64, 32, true);
        g.set_cgroup_limit(16 << 20, SimTime::ZERO);
        assert_eq!(g.rss_pages() as u64 * PAGE, 16 << 20);
        assert_eq!(g.silo_pages() as u64 * PAGE, 16 << 20);
        // Nothing on disk until cooling elapses.
        assert_eq!(g.disk_pages(), 0);
        assert_eq!(g.tick(SimTime::from_secs(59)), 0);
        let cooled = g.tick(SimTime::from_secs(60));
        assert_eq!(cooled as u64 * PAGE, 16 << 20);
        let s = g.shape();
        assert_eq!(s.swapped, 16 << 20);
        assert_eq!(s.harvestable, 64 * (1 << 20) - (16 << 20));
    }

    #[test]
    fn without_silo_pages_go_straight_to_disk() {
        let mut g = gm(64, 32, false);
        g.set_cgroup_limit(16 << 20, SimTime::ZERO);
        assert_eq!(g.silo_pages(), 0);
        assert_eq!(g.disk_pages() as u64 * PAGE, 16 << 20);
    }

    #[test]
    fn access_states_and_outcomes() {
        let mut g = gm(8, 4, true);
        assert_eq!(g.access(0, SimTime::ZERO), AccessOutcome::Hit);
        // Push everything out.
        g.set_cgroup_limit(0, SimTime::ZERO);
        assert_eq!(g.rss_pages(), 0);
        // Raise the limit so mapped-back pages can stay.
        g.disable_cgroup_limit();
        assert_eq!(g.access(0, SimTime::from_secs(1)), AccessOutcome::SiloHit);
        assert_eq!(g.access(0, SimTime::from_secs(1)), AccessOutcome::Hit);
        // Cool one page to disk and fault it.
        let mut g = gm(8, 4, true);
        g.set_cgroup_limit(0, SimTime::ZERO);
        g.tick(SimTime::from_secs(61));
        g.disable_cgroup_limit();
        assert_eq!(g.access(5, SimTime::from_secs(62)), AccessOutcome::DiskFault);
        assert_eq!(g.promotions(), 1);
    }

    #[test]
    fn pfra_prefers_cold_pages() {
        let mut g = gm(8, 4, true);
        let hot: Vec<u32> = (0..64).collect();
        // Touch hot pages many times.
        for round in 0..10 {
            for &p in &hot {
                g.access(p, SimTime::from_secs(round));
            }
        }
        // Reclaim half the app.
        g.set_cgroup_limit(2 << 20, SimTime::from_secs(11));
        // The sampled LRU should keep the vast majority of hot pages.
        let still_hot = hot
            .iter()
            .filter(|&&p| g.resident_idx[p as usize] != NOT_RESIDENT)
            .count();
        assert!(still_hot >= 56, "only {still_hot}/64 hot pages survived");
    }

    #[test]
    fn prefetch_restores_most_recent_first() {
        let mut g = gm(8, 4, false);
        g.set_cgroup_limit(1 << 20, SimTime::ZERO);
        let swapped_before = g.disk_pages();
        assert!(swapped_before > 0);
        g.disable_cgroup_limit();
        let fetched = g.prefetch(1 << 20, SimTime::from_secs(1));
        assert_eq!(fetched as u64 * PAGE, 1 << 20);
        assert_eq!(g.disk_pages(), swapped_before - fetched as u32);
        assert_eq!(g.stats.prefetched, fetched as u64);
    }

    #[test]
    fn shape_accounts_zram_residue() {
        let mut g = GuestMemory::new(
            64 << 20,
            32 << 20,
            PAGE,
            SwapDevice::Zram,
            None,
            3,
        );
        g.set_cgroup_limit(16 << 20, SimTime::ZERO);
        let s = g.shape();
        assert_eq!(s.swapped, 16 << 20);
        let residue = (s.swapped as f64 * 0.4) as u64;
        assert_eq!(s.harvestable, s.total - s.rss - residue);
    }

    #[test]
    fn composition_sums() {
        let mut g = gm(64, 48, true);
        g.set_cgroup_limit(24 << 20, SimTime::ZERO);
        g.tick(SimTime::from_secs(120));
        let s = g.shape();
        // rss + silo + swapped == app footprint
        assert_eq!(s.rss + s.silo + s.swapped, 48 << 20);
        // unallocated + app == total
        assert_eq!(s.unallocated + (48 << 20), s.total);
    }
}

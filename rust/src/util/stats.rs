//! Streaming statistics: Welford summaries, log-bucketed latency
//! histograms (HdrHistogram-style), and a simple latency recorder used by
//! every experiment harness to report avg/p50/p99 rows.

/// Running mean/variance/min/max (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY, sum: 0.0 }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean = mean;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Log-bucketed histogram over positive values with bounded relative error
/// (~2.4% with 32 subbuckets per octave) — constant memory, O(1) insert,
/// O(buckets) quantiles. Values are recorded as f64 microseconds (or any
/// positive unit).
///
/// Relationship to [`crate::metrics::Histogram`]: this is the
/// *experiment* instrument — single-threaded (`&mut self`), f64 input,
/// high resolution (sub-unit values, 32 subbuckets/octave) for the
/// simulator and figure harnesses. The `metrics` one is the *system*
/// instrument — shared (`&self`, one atomic add), integer input, 64
/// coarse pow-2 buckets, snapshot/delta/wire-friendly — and is what
/// every live path and the bench JSON artifacts use. Don't add a third.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// buckets[octave][sub]
    counts: Vec<u64>,
    n: u64,
    subbuckets: u32,
    underflow: u64,
    min_value: f64,
}

const OCTAVES: usize = 64;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::with_resolution(32, 1e-3)
    }

    /// `subbuckets` per power of two above `min_value`.
    pub fn with_resolution(subbuckets: u32, min_value: f64) -> Self {
        Histogram {
            counts: vec![0; OCTAVES * subbuckets as usize],
            n: 0,
            subbuckets,
            underflow: 0,
            min_value,
        }
    }

    fn index(&self, x: f64) -> Option<usize> {
        if !(x > self.min_value) {
            return None;
        }
        let r = x / self.min_value;
        let octave = r.log2().floor() as usize;
        if octave >= OCTAVES {
            return Some(self.counts.len() - 1);
        }
        let lo = self.min_value * (1u64 << octave.min(63)) as f64;
        let frac = (x / lo - 1.0).clamp(0.0, 0.999_999);
        let sub = (frac * self.subbuckets as f64) as usize;
        Some(octave * self.subbuckets as usize + sub)
    }

    fn bucket_value(&self, idx: usize) -> f64 {
        let octave = idx / self.subbuckets as usize;
        let sub = idx % self.subbuckets as usize;
        let lo = self.min_value * (1u64 << octave.min(63)) as f64;
        lo * (1.0 + (sub as f64 + 0.5) / self.subbuckets as f64)
    }

    pub fn record(&mut self, x: f64) {
        self.n += 1;
        match self.index(x) {
            None => self.underflow += 1,
            Some(i) => self.counts[i] += 1,
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.n as f64).ceil().max(1.0) as u64;
        let mut acc = self.underflow;
        if acc >= target {
            return self.min_value;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.bucket_value(i);
            }
        }
        self.bucket_value(self.counts.len() - 1)
    }

    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.subbuckets, other.subbuckets);
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        self.underflow += other.underflow;
    }
}

/// Latency recorder: summary + histogram, reporting in the units recorded.
#[derive(Clone, Debug, Default)]
pub struct LatencyRecorder {
    pub summary: Summary,
    pub hist: Histogram,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        LatencyRecorder { summary: Summary::new(), hist: Histogram::new() }
    }

    pub fn record(&mut self, v: f64) {
        self.summary.add(v);
        self.hist.record(v);
    }

    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.summary.merge(&other.summary);
        self.hist.merge(&other.hist);
    }

    pub fn count(&self) -> u64 {
        self.summary.count()
    }
    pub fn mean(&self) -> f64 {
        self.summary.mean()
    }
    pub fn p50(&self) -> f64 {
        self.hist.quantile(0.50)
    }
    pub fn p99(&self) -> f64 {
        self.hist.quantile(0.99)
    }
    pub fn p999(&self) -> f64 {
        self.hist.quantile(0.999)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert!((s.sum() - 40.0).abs() < 1e-12);
    }

    #[test]
    fn summary_merge_equals_sequential() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..1000).map(|_| r.normal(10.0, 3.0)).collect();
        let mut all = Summary::new();
        for &x in &xs {
            all.add(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 { a.add(x) } else { b.add(x) }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-6);
    }

    #[test]
    fn histogram_quantile_accuracy() {
        let mut h = Histogram::new();
        let mut r = Rng::new(4);
        let mut xs: Vec<f64> = Vec::new();
        for _ in 0..50_000 {
            let x = r.exponential(0.001); // mean 1000
            h.record(x);
            xs.push(x);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for &q in &[0.5, 0.9, 0.99] {
            let want = xs[((q * xs.len() as f64) as usize).min(xs.len() - 1)];
            let got = h.quantile(q);
            let rel = (got - want).abs() / want;
            assert!(rel < 0.05, "q={q} got={got} want={want} rel={rel}");
        }
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for i in 1..=100 {
            a.record(i as f64);
            b.record((i * 2) as f64);
        }
        let n = a.count() + b.count();
        a.merge(&b);
        assert_eq!(a.count(), n);
        assert!(a.quantile(1.0) >= 190.0);
    }

    #[test]
    fn recorder_reports() {
        let mut r = LatencyRecorder::new();
        for i in 1..=1000 {
            r.record(i as f64);
        }
        assert_eq!(r.count(), 1000);
        assert!((r.mean() - 500.5).abs() < 1e-9);
        assert!((r.p50() - 500.0).abs() / 500.0 < 0.05);
        assert!((r.p99() - 990.0).abs() / 990.0 < 0.05);
    }

    #[test]
    fn histogram_empty_and_underflow() {
        let mut h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        h.record(0.0); // below min_value -> underflow bucket
        assert_eq!(h.count(), 1);
        assert_eq!(h.quantile(0.5), 1e-3);
    }
}

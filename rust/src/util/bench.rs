//! Minimal benchmarking harness (criterion is not available in this
//! offline build): warmup, adaptive batching to a target duration, and
//! mean/p50/p99 per-iteration reporting. Used by every `rust/benches/*`
//! target (`harness = false`).

use std::time::{Duration, Instant};

/// One benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, unit: &str, per_iter: f64) -> String {
        let per_sec = per_iter / (self.mean_ns / 1e9);
        format!("{:.1} {unit}/s", per_sec)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// True when the short deterministic smoke mode is requested
/// (`MEMTRADE_BENCH_SMOKE=1`, set by CI's bench-smoke job): benches
/// shrink their measurement windows ~10x so the job finishes in
/// seconds while still emitting the same JSON artifacts. Relative
/// numbers (speedups) stay meaningful; absolute ones get noisier.
pub fn smoke() -> bool {
    std::env::var_os("MEMTRADE_BENCH_SMOKE").is_some_and(|v| v != "0" && !v.is_empty())
}

/// `normal_ms` scaled for the current mode ([`smoke`] divides by 10,
/// floored at 60 ms so every measurement still gets real samples).
pub fn run_for(normal_ms: u64) -> Duration {
    if smoke() {
        Duration::from_millis((normal_ms / 10).max(60))
    } else {
        Duration::from_millis(normal_ms)
    }
}

/// Best-effort raise of this process's open-file soft limit to its
/// hard limit. The `bench_e2e` connection-count sweep holds both ends
/// of up to 10k loopback connections in one process (client socket +
/// accepted socket ≈ 2 fds per simulated consumer), which blows
/// through the common 1024 default. Returns the soft limit in effect
/// afterwards; failures fall back to reporting the current limit so
/// callers can scale the sweep down instead of dying on EMFILE.
#[cfg(target_os = "linux")]
pub fn raise_nofile_limit() -> u64 {
    #[repr(C)]
    struct Rlimit {
        cur: u64,
        max: u64,
    }
    const RLIMIT_NOFILE: i32 = 7;
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    }
    let mut lim = Rlimit { cur: 0, max: 0 };
    // SAFETY: `lim` is a valid, aligned Rlimit matching the kernel's
    // 64-bit `struct rlimit` layout; getrlimit fills it or fails.
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return 1024;
    }
    if lim.cur < lim.max {
        let want = Rlimit { cur: lim.max, max: lim.max };
        // SAFETY: setrlimit only reads `want`, which outlives the call.
        if unsafe { setrlimit(RLIMIT_NOFILE, &want) } == 0 {
            return want.cur;
        }
    }
    lim.cur
}

/// Non-Linux fallback: report the conventional default without
/// touching process limits (the epoll sweep is Linux-only anyway).
#[cfg(not(target_os = "linux"))]
pub fn raise_nofile_limit() -> u64 {
    1024
}

/// Total context switches (voluntary + involuntary) this process has
/// taken, via `getrusage(RUSAGE_SELF)`. The conn_sweep bench diffs
/// this across a measurement window: a syscall-lean path shows up as
/// fewer voluntary switches per op (every blocking `epoll_wait` entry
/// with nothing ready is one). Best-effort: 0 when the call fails.
#[cfg(target_os = "linux")]
pub fn ctx_switches() -> u64 {
    // glibc's `struct rusage`: two `struct timeval` (ru_utime,
    // ru_stime = 4 longs) followed by 14 `long` counters; nvcsw and
    // nivcsw are the last two.
    #[repr(C)]
    struct Rusage {
        times: [i64; 4],
        slots: [i64; 14],
    }
    const RUSAGE_SELF: i32 = 0;
    extern "C" {
        fn getrusage(who: i32, usage: *mut Rusage) -> i32;
    }
    let mut ru = Rusage { times: [0; 4], slots: [0; 14] };
    // SAFETY: `ru` is a valid, aligned buffer matching glibc's 64-bit
    // `struct rusage` layout; getrusage fills it or fails.
    if unsafe { getrusage(RUSAGE_SELF, &mut ru) } != 0 {
        return 0;
    }
    let nvcsw = ru.slots[12].max(0) as u64;
    let nivcsw = ru.slots[13].max(0) as u64;
    nvcsw + nivcsw
}

/// Non-Linux fallback: no rusage, report 0 (columns become "n/a").
#[cfg(not(target_os = "linux"))]
pub fn ctx_switches() -> u64 {
    0
}

/// Run `f` repeatedly for ~`target` wall time (after warmup), sampling
/// per-call latency in batches; prints a criterion-like row.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_with_target(name, run_for(800), &mut f)
}

pub fn bench_with_target<F: FnMut()>(name: &str, target: Duration, f: &mut F) -> BenchResult {
    // Warmup + calibration: how many calls fit in ~10ms?
    let t0 = Instant::now();
    let mut calib = 0u64;
    while t0.elapsed() < Duration::from_millis(50) {
        f();
        calib += 1;
    }
    let per_call = t0.elapsed().as_nanos() as f64 / calib as f64;
    let batch = ((2_000_000.0 / per_call).ceil() as u64).clamp(1, 100_000);

    let mut samples: Vec<f64> = Vec::new();
    let mut iters = 0u64;
    let start = Instant::now();
    while start.elapsed() < target || samples.len() < 10 {
        let b0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(b0.elapsed().as_nanos() as f64 / batch as f64);
        iters += batch;
        if samples.len() > 100_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p99 = samples[(samples.len() * 99 / 100).min(samples.len() - 1)];
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: p50,
        p99_ns: p99,
    };
    println!(
        "{:<48} {:>12} {:>12} {:>12}   ({} iters)",
        result.name,
        fmt_ns(mean),
        fmt_ns(p50),
        fmt_ns(p99),
        iters
    );
    result
}

/// Print the standard header.
pub fn header(group: &str) {
    println!("\n== bench: {group} ==");
    println!("{:<48} {:>12} {:>12} {:>12}", "name", "mean", "p50", "p99");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench_with_target("noop-ish", Duration::from_millis(30), &mut || {
            x = x.wrapping_add(1);
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn ctx_switches_counts_and_never_goes_backwards() {
        let a = ctx_switches();
        // Sleeping forces at least one voluntary context switch.
        std::thread::sleep(Duration::from_millis(5));
        let b = ctx_switches();
        assert!(b >= a, "rusage counter went backwards: {a} -> {b}");
        assert!(b > 0, "a process that has slept has switched at least once");
    }
}

//! Deterministic PRNG (xoshiro256**, seeded via SplitMix64) plus the
//! samplers the evaluation needs: uniform, normal, exponential, and the
//! Zipfian generator YCSB uses (paper §7 runs YCSB with Zipf constant 0.7).

/// Best-effort OS entropy for seeding *unpredictable* streams (CBC IVs
/// — see `crate::crypto::secure`). Reads `/dev/urandom` where it
/// exists; the fallback mixes wall-clock nanoseconds, the process id,
/// and an ASLR-randomized address, which is far weaker — acceptable
/// only because every in-tree platform has `/dev/urandom`.
pub fn os_seed() -> u64 {
    #[cfg(unix)]
    {
        use std::io::Read;
        if let Ok(mut f) = std::fs::File::open("/dev/urandom") {
            let mut b = [0u8; 8];
            if f.read_exact(&mut b).is_ok() {
                return u64::from_le_bytes(b);
            }
        }
    }
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let stack_probe = 0u8;
    let aslr = std::ptr::addr_of!(stack_probe) as usize as u64;
    let mut z = t ^ aslr.wrapping_mul(0x9E3779B97F4A7C15) ^ ((std::process::id() as u64) << 32);
    splitmix64(&mut z)
}

/// xoshiro256** — fast, high-quality, reproducible across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// One SplitMix64 mixing step by value — for deriving independent seed
/// streams from an index (e.g. per-connection fault schedules in
/// [`crate::net::faults`]).
pub(crate) fn splitmix64_once(seed: u64) -> u64 {
    let mut s = seed;
    splitmix64(&mut s)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // Avoid the all-zero state (probability ~0 but cheap to guard).
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Derive an independent stream (for per-entity RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free bound is overkill here;
        // 128-bit multiply gives negligible bias for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform float in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Exponential with the given rate (events/unit time).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Zipfian sampler over [0, n) with parameter theta, using the
/// Gray-et-al. constant-time method YCSB uses (no per-sample harmonic
/// recomputation).
#[derive(Clone, Debug)]
pub struct Zipfian {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2: f64,
}

impl Zipfian {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0 && theta > 0.0 && theta < 1.0);
        let zetan = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Zipfian { n, theta, alpha, zetan, eta, zeta2 }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        // Exact for small n; integral approximation past a cutoff keeps
        // construction O(1)-ish for the 10^7-key workloads.
        const EXACT: u64 = 1_000_000;
        if n <= EXACT {
            (1..=n).map(|i| 1.0 / (i as f64).powf(theta)).sum()
        } else {
            let head: f64 = (1..=EXACT).map(|i| 1.0 / (i as f64).powf(theta)).sum();
            // integral of x^-theta from EXACT to n
            head + ((n as f64).powf(1.0 - theta) - (EXACT as f64).powf(1.0 - theta))
                / (1.0 - theta)
        }
    }

    /// Sample a rank in [0, n); rank 0 is the hottest item.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let raw = (self.n as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64;
        raw.min(self.n - 1)
    }

    pub fn n(&self) -> u64 {
        self.n
    }
    #[allow(dead_code)]
    fn zeta2(&self) -> f64 {
        self.zeta2
    }
}

/// Scrambled Zipfian: spreads hot ranks across the key space (as YCSB's
/// ScrambledZipfianGenerator does) so hot keys aren't adjacent.
#[derive(Clone, Debug)]
pub struct ScrambledZipfian {
    inner: Zipfian,
}

impl ScrambledZipfian {
    pub fn new(n: u64, theta: f64) -> Self {
        ScrambledZipfian { inner: Zipfian::new(n, theta) }
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let rank = self.inner.sample(rng);
        // FNV-style hash scatter, then fold into range.
        let mut h = rank.wrapping_mul(0xC6A4A7935BD1E995);
        h ^= h >> 47;
        h = h.wrapping_mul(0xC6A4A7935BD1E995);
        h % self.inner.n()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.below(10);
            assert!(v < 10);
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
            let g = r.range(5, 8);
            assert!((5..8).contains(&g));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn zipfian_skew() {
        let z = Zipfian::new(1000, 0.7);
        let mut r = Rng::new(5);
        let mut counts = vec![0u64; 1000];
        let n = 200_000;
        for _ in 0..n {
            counts[z.sample(&mut r) as usize] += 1;
        }
        // Rank 0 should dominate; top-10 should hold a large share.
        assert!(counts[0] > counts[100] * 5);
        let top10: u64 = counts[..10].iter().sum();
        assert!(top10 as f64 / n as f64 > 0.15, "top10 share {}", top10 as f64 / n as f64);
        // All samples in range (implicitly, via indexing) and every decile hit.
        assert!(counts.iter().filter(|&&c| c > 0).count() > 500);
    }

    #[test]
    fn zipfian_large_n_constructs() {
        let z = Zipfian::new(10_000_000, 0.7);
        let mut r = Rng::new(6);
        for _ in 0..1000 {
            assert!(z.sample(&mut r) < 10_000_000);
        }
    }

    #[test]
    fn scrambled_zipfian_spreads() {
        let z = ScrambledZipfian::new(1 << 20, 0.7);
        let mut r = Rng::new(8);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..1000 {
            seen.insert(z.sample(&mut r));
        }
        // Scrambling should scatter: many distinct keys, not clustered at 0.
        assert!(seen.len() > 300);
        assert!(seen.iter().any(|&k| k > (1 << 19)));
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}

//! Token-bucket rate limiter (paper §4.2, "Network Rate Limiter"): the
//! producer manager adds tokens to each consumer's bucket in proportion to
//! its allotted bandwidth; a request larger than the available tokens is
//! refused and the consumer notified.

use crate::core::SimTime;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// Classic token bucket parameterized in bytes/second, advanced on the
/// simulation (or wall) clock.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    rate_bps: f64,
    burst_bytes: f64,
    tokens: f64,
    last: SimTime,
}

impl TokenBucket {
    /// `rate_bps` bytes/second sustained; `burst_bytes` bucket depth.
    pub fn new(rate_bps: u64, burst_bytes: u64) -> Self {
        TokenBucket {
            rate_bps: rate_bps as f64,
            burst_bytes: burst_bytes as f64,
            tokens: burst_bytes as f64,
            last: SimTime::ZERO,
        }
    }

    fn refill(&mut self, now: SimTime) {
        if now > self.last {
            let dt = (now - self.last).as_secs_f64();
            self.tokens = (self.tokens + dt * self.rate_bps).min(self.burst_bytes);
            self.last = now;
        }
    }

    /// Try to admit an I/O of `bytes`; returns whether it was admitted.
    pub fn try_consume(&mut self, now: SimTime, bytes: u64) -> bool {
        self.refill(now);
        if self.tokens >= bytes as f64 {
            self.tokens -= bytes as f64;
            true
        } else {
            false
        }
    }

    /// Time until `bytes` tokens would be available (None if > burst).
    pub fn time_until(&mut self, now: SimTime, bytes: u64) -> Option<SimTime> {
        if bytes as f64 > self.burst_bytes {
            return None;
        }
        self.refill(now);
        if self.tokens >= bytes as f64 {
            Some(SimTime::ZERO)
        } else {
            let deficit = bytes as f64 - self.tokens;
            Some(SimTime::from_secs_f64(deficit / self.rate_bps))
        }
    }

    pub fn available(&mut self, now: SimTime) -> u64 {
        self.refill(now);
        self.tokens as u64
    }

    pub fn rate_bps(&self) -> u64 {
        self.rate_bps as u64
    }
}

/// Micro-byte token scale for [`AtomicTokenBucket`]: storing tokens in
/// 1e-6-byte units makes the refill `elapsed_us * rate_bps` exact
/// integer arithmetic, so sub-byte refills from frequent polling are
/// never truncated away.
const MICRO: i64 = 1_000_000;

/// Lock-free token bucket for the TCP server's shared rate limiter.
///
/// The previous design put one `Mutex<TokenBucket>` in front of every
/// connection thread, which re-serialized the request path that shard
/// partitioning had just parallelized. Here admission is a single CAS
/// loop on an atomic token counter, and refill piggybacks on whichever
/// caller first observes the clock advancing (a failed refill race
/// simply under-refills, never over-admits).
pub struct AtomicTokenBucket {
    rate_bps: u64,
    burst_micro: i64,
    tokens_micro: AtomicI64,
    last_us: AtomicU64,
}

impl AtomicTokenBucket {
    /// `rate_bps` bytes/second sustained; `burst_bytes` bucket depth.
    pub fn new(rate_bps: u64, burst_bytes: u64) -> Self {
        let burst_micro = (burst_bytes as i64).saturating_mul(MICRO);
        AtomicTokenBucket {
            rate_bps,
            burst_micro,
            tokens_micro: AtomicI64::new(burst_micro),
            last_us: AtomicU64::new(0),
        }
    }

    /// Credit tokens for the clock interval [last, `now_us`). The
    /// event loop's timerfd tick calls this every refill period so the
    /// per-op admission path never has to read a clock; the threaded
    /// path calls it inline from `try_consume`. Claiming the interval
    /// with a CAS makes concurrent callers (several loop threads, or
    /// tick + inline) safe: the loser forfeits its credit, never
    /// double-counts it.
    pub fn refill(&self, now_us: u64) {
        let last = self.last_us.load(Ordering::Acquire);
        if now_us <= last {
            return;
        }
        // Claim the interval [last, now_us). Losing the race forfeits
        // this caller's refill (conservative: never double-credits).
        if self
            .last_us
            .compare_exchange(last, now_us, Ordering::AcqRel, Ordering::Relaxed)
            .is_err()
        {
            return;
        }
        // rate_bps bytes/s == rate_bps micro-bytes/µs: exact.
        let add_u = (now_us - last) as u128 * self.rate_bps as u128;
        let add = add_u.min(i64::MAX as u128) as i64;
        let mut cur = self.tokens_micro.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_add(add).min(self.burst_micro);
            match self.tokens_micro.compare_exchange_weak(
                cur,
                next,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(c) => cur = c,
            }
        }
    }

    /// Try to admit an I/O of `bytes` at `now_us` (microseconds on any
    /// monotone clock shared by the callers).
    pub fn try_consume(&self, now_us: u64, bytes: u64) -> bool {
        self.refill(now_us);
        let need = (bytes as i64).saturating_mul(MICRO);
        let mut cur = self.tokens_micro.load(Ordering::Relaxed);
        loop {
            if cur < need {
                return false;
            }
            match self.tokens_micro.compare_exchange_weak(
                cur,
                cur - need,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(c) => cur = c,
            }
        }
    }

    /// Microseconds until `bytes` tokens could be available (None if the
    /// request exceeds the burst depth or the rate is zero).
    pub fn time_until_us(&self, now_us: u64, bytes: u64) -> Option<u64> {
        let need = (bytes as i64).saturating_mul(MICRO);
        if need > self.burst_micro || self.rate_bps == 0 {
            return None;
        }
        self.refill(now_us);
        let cur = self.tokens_micro.load(Ordering::Relaxed);
        if cur >= need {
            return Some(0);
        }
        Some(((need - cur) as u64).div_ceil(self.rate_bps))
    }

    /// Is the bucket at its burst depth? A full bucket needs no
    /// refill ticks — the event loop disarms its timer on this, which
    /// is what makes an idle throttled server zero-syscall.
    pub fn is_full(&self) -> bool {
        self.tokens_micro.load(Ordering::Relaxed) >= self.burst_micro
    }

    /// [`AtomicTokenBucket::try_consume`] minus the inline refill: the
    /// zero-clock admission path for callers whose refill arrives on a
    /// timer tick. Worst case it is one tick-interval conservative —
    /// it refuses what an exact-clock bucket would still admit — and
    /// it never over-admits.
    pub fn try_consume_unrefilled(&self, bytes: u64) -> bool {
        let need = (bytes as i64).saturating_mul(MICRO);
        let mut cur = self.tokens_micro.load(Ordering::Relaxed);
        loop {
            if cur < need {
                return false;
            }
            match self.tokens_micro.compare_exchange_weak(
                cur,
                cur - need,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(c) => cur = c,
            }
        }
    }

    /// [`AtomicTokenBucket::time_until_us`] minus the inline refill,
    /// for the same tick-refilled callers. The estimate may overshoot
    /// by up to one tick interval (tokens credited since the last tick
    /// are not visible yet); retry semantics are unchanged.
    pub fn time_until_us_unrefilled(&self, bytes: u64) -> Option<u64> {
        let need = (bytes as i64).saturating_mul(MICRO);
        if need > self.burst_micro || self.rate_bps == 0 {
            return None;
        }
        let cur = self.tokens_micro.load(Ordering::Relaxed);
        if cur >= need {
            return Some(0);
        }
        Some(((need - cur) as u64).div_ceil(self.rate_bps))
    }

    pub fn rate_bps(&self) -> u64 {
        self.rate_bps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_burst() {
        let mut tb = TokenBucket::new(1000, 500);
        let t0 = SimTime::ZERO;
        assert!(tb.try_consume(t0, 500));
        assert!(!tb.try_consume(t0, 1));
    }

    #[test]
    fn refills_at_rate() {
        let mut tb = TokenBucket::new(1000, 1000);
        assert!(tb.try_consume(SimTime::ZERO, 1000));
        // After 0.5s, 500 tokens back.
        assert!(!tb.try_consume(SimTime::from_millis(500), 501));
        assert!(tb.try_consume(SimTime::from_millis(500), 500));
    }

    #[test]
    fn never_exceeds_burst() {
        let mut tb = TokenBucket::new(1_000_000, 2000);
        assert_eq!(tb.available(SimTime::from_hours(5)), 2000);
    }

    #[test]
    fn never_over_admits() {
        // Property: over any schedule, admitted bytes <= burst + rate * elapsed.
        let mut tb = TokenBucket::new(10_000, 1_000);
        let mut admitted = 0u64;
        let mut rng = crate::util::rng::Rng::new(9);
        let mut now = SimTime::ZERO;
        for _ in 0..if cfg!(miri) { 500 } else { 10_000 } {
            now += SimTime::from_micros(rng.below(2_000));
            let req = rng.below(400) + 1;
            if tb.try_consume(now, req) {
                admitted += req;
            }
        }
        let bound = 1_000.0 + 10_000.0 * now.as_secs_f64() + 1.0;
        assert!(
            (admitted as f64) <= bound,
            "admitted {admitted} > bound {bound}"
        );
    }

    #[test]
    fn time_until_estimates() {
        let mut tb = TokenBucket::new(1000, 1000);
        assert!(tb.try_consume(SimTime::ZERO, 1000));
        let wait = tb.time_until(SimTime::ZERO, 100).unwrap();
        assert!((wait.as_secs_f64() - 0.1).abs() < 1e-6);
        assert_eq!(tb.time_until(SimTime::ZERO, 5000), None);
    }

    #[test]
    fn atomic_admits_up_to_burst_and_refills() {
        let tb = AtomicTokenBucket::new(1000, 500);
        assert!(tb.try_consume(0, 500));
        assert!(!tb.try_consume(0, 1));
        // After 0.5s at 1000 B/s, 500 bytes are back.
        assert!(!tb.try_consume(500_000, 501));
        assert!(tb.try_consume(500_000, 500));
    }

    #[test]
    fn atomic_time_until_estimates() {
        let tb = AtomicTokenBucket::new(1000, 1000);
        assert!(tb.try_consume(0, 1000));
        // 100 bytes at 1000 B/s = 0.1s.
        assert_eq!(tb.time_until_us(0, 100), Some(100_000));
        assert_eq!(tb.time_until_us(0, 5000), None);
    }

    #[test]
    fn atomic_sub_byte_refills_not_lost() {
        // 1 B/s polled every 100µs: naive byte-granular refill would
        // truncate every increment to zero forever.
        let tb = AtomicTokenBucket::new(1, 10);
        assert!(tb.try_consume(0, 10));
        // Either schedule polls 1 simulated second in sub-byte steps;
        // Miri takes fewer, coarser polls.
        let (polls, step_us) = if cfg!(miri) { (1_000u64, 1_000) } else { (10_000, 100) };
        let mut now = 0u64;
        for _ in 0..polls {
            now += step_us;
            let _ = tb.try_consume(now, 10);
        }
        // 1 second elapsed: exactly 1 byte should have accumulated.
        assert!(tb.try_consume(now, 1));
        assert!(!tb.try_consume(now, 1));
    }

    #[test]
    fn atomic_tick_refill_matches_inline_refill() {
        // The tick-driven split (explicit refill + unrefilled consume)
        // admits exactly what the inline path admits when the tick
        // carries the same clock.
        let tb = AtomicTokenBucket::new(1000, 500);
        assert!(tb.is_full());
        assert!(tb.try_consume_unrefilled(500));
        assert!(!tb.is_full());
        assert!(!tb.try_consume_unrefilled(1));
        // Between ticks the unrefilled path is frozen: no credit yet.
        assert_eq!(tb.time_until_us_unrefilled(100), Some(100_000));
        tb.refill(500_000); // the 0.5s tick lands
        assert!(!tb.try_consume_unrefilled(501));
        assert!(tb.try_consume_unrefilled(500));
        // Over-burst requests are refused outright, exactly like
        // `time_until_us`.
        assert_eq!(tb.time_until_us_unrefilled(5000), None);
        tb.refill(10_000_000);
        assert!(tb.is_full());
    }

    #[test]
    fn atomic_concurrent_never_over_admits() {
        use std::sync::Arc;
        let rate = 1_000_000u64;
        let burst = 10_000u64;
        let tb = Arc::new(AtomicTokenBucket::new(rate, burst));
        let clock = Arc::new(AtomicU64::new(0));
        let admitted = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let tb = tb.clone();
                let clock = clock.clone();
                let admitted = admitted.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::util::rng::Rng::new(200 + t);
                    for _ in 0..if cfg!(miri) { 500 } else { 20_000 } {
                        let now = clock.fetch_add(2, Ordering::Relaxed) + 2;
                        let req = 1 + rng.below(400);
                        if tb.try_consume(now, req) {
                            admitted.fetch_add(req, Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Refill credits are bounded by rate * elapsed (claimed intervals
        // never overlap), so admission is bounded by burst + rate * t.
        let elapsed_us = clock.load(Ordering::Relaxed);
        let bound = burst + rate * elapsed_us / 1_000_000 + 1;
        let got = admitted.load(Ordering::Relaxed);
        assert!(got <= bound, "admitted {got} > bound {bound}");
    }
}

//! Token-bucket rate limiter (paper §4.2, "Network Rate Limiter"): the
//! producer manager adds tokens to each consumer's bucket in proportion to
//! its allotted bandwidth; a request larger than the available tokens is
//! refused and the consumer notified.

use crate::core::SimTime;

/// Classic token bucket parameterized in bytes/second, advanced on the
/// simulation (or wall) clock.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    rate_bps: f64,
    burst_bytes: f64,
    tokens: f64,
    last: SimTime,
}

impl TokenBucket {
    /// `rate_bps` bytes/second sustained; `burst_bytes` bucket depth.
    pub fn new(rate_bps: u64, burst_bytes: u64) -> Self {
        TokenBucket {
            rate_bps: rate_bps as f64,
            burst_bytes: burst_bytes as f64,
            tokens: burst_bytes as f64,
            last: SimTime::ZERO,
        }
    }

    fn refill(&mut self, now: SimTime) {
        if now > self.last {
            let dt = (now - self.last).as_secs_f64();
            self.tokens = (self.tokens + dt * self.rate_bps).min(self.burst_bytes);
            self.last = now;
        }
    }

    /// Try to admit an I/O of `bytes`; returns whether it was admitted.
    pub fn try_consume(&mut self, now: SimTime, bytes: u64) -> bool {
        self.refill(now);
        if self.tokens >= bytes as f64 {
            self.tokens -= bytes as f64;
            true
        } else {
            false
        }
    }

    /// Time until `bytes` tokens would be available (None if > burst).
    pub fn time_until(&mut self, now: SimTime, bytes: u64) -> Option<SimTime> {
        if bytes as f64 > self.burst_bytes {
            return None;
        }
        self.refill(now);
        if self.tokens >= bytes as f64 {
            Some(SimTime::ZERO)
        } else {
            let deficit = bytes as f64 - self.tokens;
            Some(SimTime::from_secs_f64(deficit / self.rate_bps))
        }
    }

    pub fn available(&mut self, now: SimTime) -> u64 {
        self.refill(now);
        self.tokens as u64
    }

    pub fn rate_bps(&self) -> u64 {
        self.rate_bps as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_burst() {
        let mut tb = TokenBucket::new(1000, 500);
        let t0 = SimTime::ZERO;
        assert!(tb.try_consume(t0, 500));
        assert!(!tb.try_consume(t0, 1));
    }

    #[test]
    fn refills_at_rate() {
        let mut tb = TokenBucket::new(1000, 1000);
        assert!(tb.try_consume(SimTime::ZERO, 1000));
        // After 0.5s, 500 tokens back.
        assert!(!tb.try_consume(SimTime::from_millis(500), 501));
        assert!(tb.try_consume(SimTime::from_millis(500), 500));
    }

    #[test]
    fn never_exceeds_burst() {
        let mut tb = TokenBucket::new(1_000_000, 2000);
        assert_eq!(tb.available(SimTime::from_hours(5)), 2000);
    }

    #[test]
    fn never_over_admits() {
        // Property: over any schedule, admitted bytes <= burst + rate * elapsed.
        let mut tb = TokenBucket::new(10_000, 1_000);
        let mut admitted = 0u64;
        let mut rng = crate::util::rng::Rng::new(9);
        let mut now = SimTime::ZERO;
        for _ in 0..10_000 {
            now += SimTime::from_micros(rng.below(2_000));
            let req = rng.below(400) + 1;
            if tb.try_consume(now, req) {
                admitted += req;
            }
        }
        let bound = 1_000.0 + 10_000.0 * now.as_secs_f64() + 1.0;
        assert!(
            (admitted as f64) <= bound,
            "admitted {admitted} > bound {bound}"
        );
    }

    #[test]
    fn time_until_estimates() {
        let mut tb = TokenBucket::new(1000, 1000);
        assert!(tb.try_consume(SimTime::ZERO, 1000));
        let wait = tb.time_until(SimTime::ZERO, 100).unwrap();
        assert!((wait.as_secs_f64() - 0.1).abs() < 1e-6);
        assert_eq!(tb.time_until(SimTime::ZERO, 5000), None);
    }
}

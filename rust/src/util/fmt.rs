//! Experiment-output formatting: aligned text/markdown tables and CSV
//! emitters used by every figure harness, plus the `gb`/`pct`/`ms`
//! value formatters. Moved here from `metrics/` so that module owns
//! telemetry only (registries + histograms), not presentation.

/// A simple column-aligned table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as a markdown table.
    pub fn markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| {
            let mut line = String::from("|");
            for (c, width) in cells.iter().zip(w) {
                line.push_str(&format!(" {c:<width$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &w));
        out.push('|');
        for width in &w {
            out.push_str(&format!("{:-<1$}|", "", width + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
        }
        out
    }

    /// Render as CSV.
    pub fn csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(esc).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.markdown());
    }
}

/// Format helpers for experiment output.
pub fn gb(bytes: u64) -> String {
    format!("{:.1} GB", bytes as f64 / (1u64 << 30) as f64)
}
pub fn pct(frac: f64) -> String {
    format!("{:.1}%", frac * 100.0)
}
pub fn ms(us: f64) -> String {
    format!("{:.2} ms", us / 1000.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["a", "1"]).row(vec!["long-name", "2"]);
        let md = t.markdown();
        assert!(md.contains("| name      | value |"));
        assert!(md.contains("| long-name | 2     |"));
        assert!(md.lines().count() == 4);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x,y", "has \"quote\""]);
        let csv = t.csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"has \"\"quote\"\"\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new(vec!["a", "b"]).row(vec!["only-one"]);
    }

    #[test]
    fn formatters() {
        assert_eq!(gb(1 << 30), "1.0 GB");
        assert_eq!(pct(0.123), "12.3%");
        assert_eq!(ms(1500.0), "1.50 ms");
    }
}

//! Fixed-capacity ring-buffer time series used for producer usage
//! reporting (broker §5.1 keeps a sliding window of usage samples per
//! producer that feeds the AOT forecast artifact).

/// Ring buffer of the most recent `capacity` f32 samples.
#[derive(Clone, Debug)]
pub struct TimeSeries {
    buf: Vec<f32>,
    head: usize,
    len: usize,
}

impl TimeSeries {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        TimeSeries { buf: vec![0.0; capacity], head: 0, len: 0 }
    }

    pub fn push(&mut self, v: f32) {
        self.buf[self.head] = v;
        self.head = (self.head + 1) % self.buf.len();
        self.len = (self.len + 1).min(self.buf.len());
    }

    pub fn len(&self) -> usize {
        self.len
    }
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }
    pub fn is_full(&self) -> bool {
        self.len == self.buf.len()
    }

    pub fn last(&self) -> Option<f32> {
        if self.len == 0 {
            None
        } else {
            let idx = (self.head + self.buf.len() - 1) % self.buf.len();
            Some(self.buf[idx])
        }
    }

    /// Oldest-to-newest copy, padded on the LEFT with the oldest sample
    /// (or `pad` when empty) to exactly `n` values — the fixed-shape input
    /// the compiled forecast artifact expects.
    pub fn window_padded(&self, n: usize, pad: f32) -> Vec<f32> {
        let mut out = Vec::with_capacity(n);
        let chron = self.to_vec();
        let take = chron.len().min(n);
        let fill = if chron.is_empty() { pad } else { chron[0] };
        for _ in 0..(n - take) {
            out.push(fill);
        }
        out.extend_from_slice(&chron[chron.len() - take..]);
        out
    }

    /// Oldest-to-newest copy of the live samples.
    pub fn to_vec(&self) -> Vec<f32> {
        let cap = self.buf.len();
        let start = (self.head + cap - self.len) % cap;
        (0..self.len).map(|i| self.buf[(start + i) % cap]).collect()
    }

    pub fn mean(&self) -> f32 {
        if self.len == 0 {
            return 0.0;
        }
        self.to_vec().iter().sum::<f32>() / self.len as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_semantics() {
        let mut ts = TimeSeries::new(4);
        assert!(ts.is_empty());
        for i in 1..=6 {
            ts.push(i as f32);
        }
        assert!(ts.is_full());
        assert_eq!(ts.to_vec(), vec![3.0, 4.0, 5.0, 6.0]);
        assert_eq!(ts.last(), Some(6.0));
        assert_eq!(ts.len(), 4);
    }

    #[test]
    fn window_padding() {
        let mut ts = TimeSeries::new(8);
        ts.push(5.0);
        ts.push(7.0);
        let w = ts.window_padded(5, 0.0);
        assert_eq!(w, vec![5.0, 5.0, 5.0, 5.0, 7.0]);
        let empty = TimeSeries::new(4).window_padded(3, 2.5);
        assert_eq!(empty, vec![2.5, 2.5, 2.5]);
    }

    #[test]
    fn window_truncates_to_recent() {
        let mut ts = TimeSeries::new(10);
        for i in 0..10 {
            ts.push(i as f32);
        }
        assert_eq!(ts.window_padded(3, 0.0), vec![7.0, 8.0, 9.0]);
    }

    #[test]
    fn mean() {
        let mut ts = TimeSeries::new(3);
        ts.push(1.0);
        ts.push(2.0);
        ts.push(3.0);
        ts.push(4.0); // evicts 1.0
        assert!((ts.mean() - 3.0).abs() < 1e-6);
    }
}

//! Foundational utilities built from scratch (no external deps): a fast
//! deterministic RNG with the samplers the workloads need, the windowed
//! order-statistics tree the harvester's p99 estimators use, streaming
//! statistics, a token-bucket rate limiter, time-series helpers, and a
//! jittered exponential-backoff schedule for reconnect loops.

pub mod avl;
pub mod backoff;
pub mod bench;
pub mod clock;
pub mod fmt;
pub mod hash;
pub mod rng;
pub mod stats;
pub mod timeseries;
pub mod token_bucket;

pub use avl::WindowedDist;
pub use backoff::Backoff;
pub use rng::Rng;
pub use stats::{Histogram, LatencyRecorder, Summary};
pub use timeseries::TimeSeries;
pub use token_bucket::{AtomicTokenBucket, TokenBucket};

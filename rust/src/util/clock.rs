//! The wall-clock shims — the only place (enforced by `memtrade lint`,
//! rule `clock`) where calendar time enters the system outside the
//! RNG's seed fallback.
//!
//! Everything downstream of these functions takes time as a *value*:
//! the lease state machine, replication events, and both wire codecs
//! are clock-agnostic so they can be driven by the simulator and
//! replayed deterministically. Daemon loops that need calendar time
//! (session ids, unique on-disk names) call these shims instead of
//! `SystemTime::now` directly, which keeps the lint allowlist at two
//! files and makes every wall-clock read greppable.

use std::time::{SystemTime, UNIX_EPOCH};

/// Microseconds since the Unix epoch (0 if the system clock is set
/// before 1970 — callers use this for uniqueness, not for ordering
/// guarantees).
pub fn unix_micros() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

/// Nanoseconds since the Unix epoch, truncated to u64 (wraps after
/// ~584 years; same uniqueness-not-ordering contract as
/// [`unix_micros`]).
pub fn unix_nanos() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clocks_are_nonzero_and_consistent() {
        let us = unix_micros();
        let ns = unix_nanos();
        assert!(us > 1_500_000_000_000_000, "clock before ~2017: {us}");
        // The two reads straddle at most a few seconds.
        assert!(ns / 1000 >= us);
        assert!(ns / 1000 - us < 10_000_000, "us={us} ns={ns}");
    }
}

//! Windowed order-statistics distribution — the data structure behind the
//! harvester's baseline and recent performance estimators (paper §4.1):
//! "An efficient AVL-tree data structure is used to track these points,
//! which are discarded after an expiration time."
//!
//! [`WindowedDist`] keeps (timestamp, value) samples, supports O(log n)
//! insertion, O(log n) arbitrary-quantile queries via subtree counts, and
//! expiry of samples older than the window.  Duplicate values are handled
//! with per-node multiplicity plus a FIFO of timestamps for expiry.

use crate::core::SimTime;
use std::collections::VecDeque;

/// AVL node storing one distinct value with multiplicity.
struct Node {
    value: f64,
    count: u32,
    left: Option<Box<Node>>,
    right: Option<Box<Node>>,
    height: i32,
    /// Total multiplicity in this subtree (for order statistics).
    size: u32,
}

impl Node {
    fn new(value: f64) -> Box<Node> {
        Box::new(Node { value, count: 1, left: None, right: None, height: 1, size: 1 })
    }

    fn update(&mut self) {
        let (lh, ls) = self.left.as_ref().map_or((0, 0), |n| (n.height, n.size));
        let (rh, rs) = self.right.as_ref().map_or((0, 0), |n| (n.height, n.size));
        self.height = 1 + lh.max(rh);
        self.size = self.count + ls + rs;
    }

    fn balance_factor(&self) -> i32 {
        let lh = self.left.as_ref().map_or(0, |n| n.height);
        let rh = self.right.as_ref().map_or(0, |n| n.height);
        lh - rh
    }
}

fn rotate_right(mut node: Box<Node>) -> Box<Node> {
    let mut left = node.left.take().expect("rotate_right without left child");
    node.left = left.right.take();
    node.update();
    left.right = Some(node);
    left.update();
    left
}

fn rotate_left(mut node: Box<Node>) -> Box<Node> {
    let mut right = node.right.take().expect("rotate_left without right child");
    node.right = right.left.take();
    node.update();
    right.left = Some(node);
    right.update();
    right
}

fn rebalance(mut node: Box<Node>) -> Box<Node> {
    node.update();
    let bf = node.balance_factor();
    if bf > 1 {
        if node.left.as_ref().unwrap().balance_factor() < 0 {
            node.left = Some(rotate_left(node.left.take().unwrap()));
        }
        node = rotate_right(node);
    } else if bf < -1 {
        if node.right.as_ref().unwrap().balance_factor() > 0 {
            node.right = Some(rotate_right(node.right.take().unwrap()));
        }
        node = rotate_left(node);
    }
    node
}

fn insert(node: Option<Box<Node>>, value: f64) -> Box<Node> {
    match node {
        None => Node::new(value),
        Some(mut n) => {
            if value == n.value {
                n.count += 1;
                n.update();
                n
            } else if value < n.value {
                n.left = Some(insert(n.left.take(), value));
                rebalance(n)
            } else {
                n.right = Some(insert(n.right.take(), value));
                rebalance(n)
            }
        }
    }
}

fn min_value(node: &Node) -> f64 {
    node.left.as_ref().map_or(node.value, |l| min_value(l))
}

fn remove(node: Option<Box<Node>>, value: f64) -> Option<Box<Node>> {
    let mut n = node?;
    if value < n.value {
        n.left = remove(n.left.take(), value);
    } else if value > n.value {
        n.right = remove(n.right.take(), value);
    } else {
        if n.count > 1 {
            n.count -= 1;
            n.update();
            return Some(n);
        }
        match (n.left.take(), n.right.take()) {
            (None, None) => return None,
            (Some(l), None) => return Some(l),
            (None, Some(r)) => return Some(r),
            (Some(l), Some(r)) => {
                let succ = min_value(&r);
                n.value = succ;
                n.count = 1;
                // Remove exactly one instance of succ from the right subtree.
                n.left = Some(l);
                n.right = remove(Some(r), succ);
                // Transfer multiplicity: the successor may have had count > 1;
                // remove() above removed one instance, the rest stay in place,
                // which is fine — values are equal-keyed nodes.
            }
        }
    }
    Some(rebalance(n))
}

/// k-th smallest (0-based) by multiplicity.
fn kth(node: &Node, k: u32) -> f64 {
    let ls = node.left.as_ref().map_or(0, |n| n.size);
    if k < ls {
        kth(node.left.as_ref().unwrap(), k)
    } else if k < ls + node.count {
        node.value
    } else {
        kth(node.right.as_ref().unwrap(), k - ls - node.count)
    }
}

/// Number of samples strictly less than `value`.
fn rank_below(node: Option<&Node>, value: f64) -> u32 {
    match node {
        None => 0,
        Some(n) => {
            if value <= n.value {
                rank_below(n.left.as_deref(), value)
            } else {
                let left_size = n.left.as_ref().map_or(0, |l| l.size);
                left_size + n.count + rank_below(n.right.as_deref(), value)
            }
        }
    }
}

/// Time-windowed distribution with O(log n) quantiles.
pub struct WindowedDist {
    root: Option<Box<Node>>,
    /// FIFO of (timestamp, value) for expiry.
    queue: VecDeque<(SimTime, f64)>,
    window: SimTime,
}

impl WindowedDist {
    pub fn new(window: SimTime) -> Self {
        WindowedDist { root: None, queue: VecDeque::new(), window }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
    pub fn window(&self) -> SimTime {
        self.window
    }

    /// Insert a sample observed at `now`, then expire old samples.
    pub fn insert(&mut self, now: SimTime, value: f64) {
        self.root = Some(insert(self.root.take(), value));
        self.queue.push_back((now, value));
        self.expire(now);
    }

    /// Drop samples older than `now - window`.
    pub fn expire(&mut self, now: SimTime) {
        let cutoff = now.saturating_sub(self.window);
        while let Some(&(t, v)) = self.queue.front() {
            if t < cutoff {
                self.queue.pop_front();
                self.root = remove(self.root.take(), v);
            } else {
                break;
            }
        }
    }

    /// Quantile in [0, 1]; e.g. 0.99 for p99. None when empty.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let root = self.root.as_ref()?;
        let n = root.size;
        if n == 0 {
            return None;
        }
        let k = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u32)
            .saturating_sub(1)
            .min(n - 1);
        Some(kth(root, k))
    }

    pub fn min(&self) -> Option<f64> {
        self.quantile(0.0)
    }
    pub fn max(&self) -> Option<f64> {
        self.quantile(1.0)
    }

    /// Fraction of samples strictly below `value`.
    pub fn cdf(&self, value: f64) -> f64 {
        match &self.root {
            None => 0.0,
            Some(r) => rank_below(Some(r), value) as f64 / r.size as f64,
        }
    }

    pub fn mean(&self) -> Option<f64> {
        if self.queue.is_empty() {
            return None;
        }
        Some(self.queue.iter().map(|&(_, v)| v).sum::<f64>() / self.queue.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Oracle: sorted-vec quantile with the same ceil convention.
    fn oracle_quantile(values: &mut Vec<f64>, q: f64) -> f64 {
        values.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = values.len();
        let k = ((q * n as f64).ceil() as usize).saturating_sub(1).min(n - 1);
        values[k]
    }

    #[test]
    fn quantiles_match_sorted_vec_oracle() {
        let mut r = Rng::new(21);
        let mut d = WindowedDist::new(SimTime::from_hours(100));
        let mut vals = Vec::new();
        for i in 0..5000 {
            let v = (r.f64() * 1000.0).round() / 10.0; // many duplicates
            d.insert(SimTime::from_secs(i), v);
            vals.push(v);
        }
        for &q in &[0.0, 0.01, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let got = d.quantile(q).unwrap();
            let want = oracle_quantile(&mut vals.clone(), q);
            assert_eq!(got, want, "q={q}");
        }
    }

    #[test]
    fn expiry_removes_old_samples() {
        let mut d = WindowedDist::new(SimTime::from_secs(10));
        for i in 0..20 {
            d.insert(SimTime::from_secs(i), i as f64);
        }
        // At t=19 the cutoff is t=9: samples 0..9 expired.
        assert_eq!(d.len(), 11);
        assert_eq!(d.min().unwrap(), 9.0);
        assert_eq!(d.max().unwrap(), 19.0);
    }

    #[test]
    fn expiry_with_duplicates() {
        let mut d = WindowedDist::new(SimTime::from_secs(5));
        for i in 0..10 {
            d.insert(SimTime::from_secs(i), 1.0); // all identical
        }
        assert_eq!(d.len(), 6);
        assert_eq!(d.quantile(0.5), Some(1.0));
        d.insert(SimTime::from_secs(100), 2.0);
        assert_eq!(d.len(), 1);
        assert_eq!(d.quantile(0.5), Some(2.0));
    }

    #[test]
    fn randomized_vs_oracle_with_expiry() {
        let mut r = Rng::new(77);
        let mut d = WindowedDist::new(SimTime::from_secs(50));
        let mut log: Vec<(u64, f64)> = Vec::new();
        for step in 0..3000u64 {
            let v = r.normal(100.0, 15.0);
            d.insert(SimTime::from_secs(step), v);
            log.push((step, v));
            if step % 97 == 0 && step > 0 {
                let cutoff = step.saturating_sub(50);
                let mut live: Vec<f64> =
                    log.iter().filter(|&&(t, _)| t >= cutoff).map(|&(_, v)| v).collect();
                assert_eq!(d.len(), live.len(), "step {step}");
                let got = d.quantile(0.99).unwrap();
                let want = oracle_quantile(&mut live, 0.99);
                assert_eq!(got, want, "step {step}");
            }
        }
    }

    #[test]
    fn cdf_fraction() {
        let mut d = WindowedDist::new(SimTime::from_hours(1));
        for i in 0..100 {
            d.insert(SimTime::from_secs(i), i as f64);
        }
        assert!((d.cdf(50.0) - 0.5).abs() < 0.02);
        assert_eq!(d.cdf(0.0), 0.0);
        assert!((d.cdf(1e9) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_behaviour() {
        let d = WindowedDist::new(SimTime::from_secs(1));
        assert!(d.is_empty());
        assert_eq!(d.quantile(0.5), None);
        assert_eq!(d.mean(), None);
        assert_eq!(d.cdf(1.0), 0.0);
    }

    #[test]
    fn mean_matches() {
        let mut d = WindowedDist::new(SimTime::from_hours(1));
        for i in 1..=10 {
            d.insert(SimTime::from_secs(i), i as f64);
        }
        assert!((d.mean().unwrap() - 5.5).abs() < 1e-12);
    }
}

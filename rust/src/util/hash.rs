//! Shared non-cryptographic hashing: FNV-1a 64, used for shard routing
//! ([`crate::kv::ShardedKvStore`]) and SHARDS spatial sampling
//! ([`crate::consumer::mrc`]). Cheap, allocation-free, good spread for
//! short keys.

/// 64-bit FNV-1a over a byte string.
#[inline]
pub fn fnv1a_64(key: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in key {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // FNV-1a 64 reference values.
        assert_eq!(fnv1a_64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn spreads_sequential_keys() {
        let mut buckets = [0u32; 8];
        for i in 0..8000u32 {
            buckets[(fnv1a_64(format!("user{i}").as_bytes()) % 8) as usize] += 1;
        }
        for (i, &n) in buckets.iter().enumerate() {
            assert!(n > 500, "bucket {i} starved: {n}");
        }
    }
}

//! Capped exponential backoff with seeded jitter, for every reconnect
//! loop in the marketplace.
//!
//! A fixed retry delay has two failure modes this module exists to
//! kill: it is either too long (a consumer pool that waits 10s to
//! re-dial a broker that restarted in 100ms) or, worse, synchronized —
//! at a broker failover every agent and pool in the fleet notices the
//! dead primary within one heartbeat of each other, and with a fixed
//! delay they all hammer the standby at the same instant, repeatedly.
//! The schedule here doubles a per-attempt window up to a cap and
//! draws the actual delay uniformly from the window's upper half
//! ("equal jitter"), so retries stay prompt early, bounded late, and
//! de-correlated across clients seeded differently.
//!
//! The schedule is clock-free — it returns [`Duration`]s and never
//! sleeps — so callers own the waiting and tests assert the exact
//! sequence deterministically.

use crate::util::rng::Rng;
use std::time::Duration;

/// Deterministic capped-exponential backoff schedule.
pub struct Backoff {
    base_us: u64,
    cap_us: u64,
    rng: Rng,
    attempt: u32,
}

impl Backoff {
    /// A schedule starting at `base` and doubling per attempt up to
    /// `cap`. Jitter is drawn from `seed`: clients seeded differently
    /// (e.g. by participant id) spread out even when they start
    /// retrying at the same instant.
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Self {
        Backoff {
            base_us: (base.as_micros() as u64).max(1),
            cap_us: (cap.as_micros() as u64).max(1),
            rng: Rng::new(seed),
            attempt: 0,
        }
    }

    /// The delay to wait before the next attempt; each call advances
    /// the schedule. The `n`-th delay (0-based) lies in
    /// `[w/2, w]` where `w = min(base << n, cap)` — never below half
    /// the window (prompt-but-spread), never above the cap.
    pub fn next_delay(&mut self) -> Duration {
        // Stop shifting once the window has surely reached the cap;
        // `checked_shl`-style guard against `base << 63` overflow.
        let shift = self.attempt.min(32);
        let window = self.base_us.saturating_mul(1u64 << shift).min(self.cap_us);
        self.attempt = self.attempt.saturating_add(1);
        let half = window / 2;
        Duration::from_micros(half + self.rng.below(window - half + 1))
    }

    /// Back to the first-attempt window after a success; the jitter
    /// stream keeps advancing (re-correlating the fleet on every
    /// success would defeat the point).
    pub fn reset(&mut self) {
        self.attempt = 0;
    }

    /// Attempts since the last [`Self::reset`].
    pub fn attempts(&self) -> u32 {
        self.attempt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: u64 = 1_000;

    fn schedule(seed: u64, n: usize) -> Vec<u64> {
        let mut b = Backoff::new(Duration::from_millis(100), Duration::from_secs(5), seed);
        (0..n).map(|_| b.next_delay().as_micros() as u64).collect()
    }

    #[test]
    fn delays_grow_exponentially_within_jitter_bounds_then_cap() {
        let delays = schedule(7, 12);
        for (i, d) in delays.iter().enumerate() {
            let window = (100 * MS << i.min(32)).min(5_000 * MS);
            assert!(*d >= window / 2, "attempt {i}: {d} below {}", window / 2);
            assert!(*d <= window, "attempt {i}: {d} above {window}");
        }
        // By attempt 6 (100ms << 6 = 6.4s) the window is the 5s cap.
        for (i, d) in delays.iter().enumerate().skip(6) {
            assert!(*d >= 2_500 * MS && *d <= 5_000 * MS, "attempt {i}: {d}");
        }
    }

    #[test]
    fn schedule_is_deterministic_per_seed_and_spread_across_seeds() {
        assert_eq!(schedule(7, 8), schedule(7, 8));
        // Two clients seeded differently never retry in lockstep for
        // a whole schedule (the anti-thundering-herd property).
        assert_ne!(schedule(7, 8), schedule(8, 8));
    }

    #[test]
    fn reset_restarts_the_window_without_replaying_jitter() {
        let mut b = Backoff::new(Duration::from_millis(100), Duration::from_secs(5), 7);
        for _ in 0..5 {
            b.next_delay();
        }
        assert_eq!(b.attempts(), 5);
        b.reset();
        assert_eq!(b.attempts(), 0);
        let d = b.next_delay().as_micros() as u64;
        assert!((50 * MS..=100 * MS).contains(&d), "post-reset delay {d}");
    }

    #[test]
    fn degenerate_configs_stay_sane() {
        // base > cap: every delay clamps into the cap window.
        let mut b = Backoff::new(Duration::from_secs(10), Duration::from_secs(1), 3);
        for _ in 0..4 {
            let d = b.next_delay();
            assert!(d >= Duration::from_millis(500) && d <= Duration::from_secs(1));
        }
        // Zero base: still advances (1µs floor), never panics.
        let mut b = Backoff::new(Duration::ZERO, Duration::ZERO, 3);
        for _ in 0..70 {
            assert!(b.next_delay() <= Duration::from_micros(1));
        }
    }
}

//! Hash-partitioned producer store: N independently locked [`KvStore`]
//! shards behind one byte budget.
//!
//! The TCP producer-store server used to funnel every connection through
//! a single `Mutex<KvStore>`; under multi-tenant traffic that one lock
//! was the whole system's throughput ceiling. Here keys are partitioned
//! by a 64-bit FNV-1a hash, so concurrent GET/PUT/DELETE on different
//! shards never contend. Stats aggregate across shards, and the
//! harvester-facing budget operations (`shrink_to` / `grow_to` /
//! `defragment`) apply proportionally to every shard's budget.
//!
//! Budget semantics: the total byte budget is split across shards at
//! construction (largest-remainder, so shard budgets always sum to the
//! total). Eviction is per shard — a hot shard evicts while a cold one
//! has headroom — and the largest storable pair is bounded by a *shard*
//! budget (~total/N), not the total. That is the same trade Redis
//! Cluster and memcached make for lock-free scaling; to keep the cap
//! sane for small stores, construction never shards below
//! [`MIN_SHARD_BYTES`] per shard.

use super::store::{KvStats, KvStore};
use crate::metrics::Histogram;
use crate::util::hash::fnv1a_64;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

/// Split `total` into `n` parts that differ by at most one byte and sum
/// exactly to `total`.
fn even_split(total: usize, n: usize) -> Vec<usize> {
    let base = total / n;
    let rem = total % n;
    (0..n).map(|i| base + usize::from(i < rem)).collect()
}

/// Floor on the per-shard byte budget: requesting more shards than
/// `max_bytes / MIN_SHARD_BYTES` silently uses fewer, so tiny stores
/// don't end up with per-shard budgets (and thus max-value caps) of a
/// few kilobytes.
pub const MIN_SHARD_BYTES: usize = 1 << 20;

/// A producer store hash-partitioned across independently locked shards.
/// All methods take `&self`; the per-shard mutexes provide interior
/// mutability so server connection threads can share one instance.
pub struct ShardedKvStore {
    shards: Vec<Mutex<KvStore>>,
    /// Round-robin cursor so `sample_key` doesn't always drain shard 0.
    sample_cursor: AtomicUsize,
    /// Telemetry: shard-lock hold time (µs), recorded when a
    /// [`ShardGuard`] drops. `None` (the default) costs nothing — the
    /// guard then skips even the clock reads.
    lock_hold_us: Option<Arc<Histogram>>,
}

/// A held shard lock. Derefs to the underlying [`KvStore`]; when the
/// owning store is instrumented ([`ShardedKvStore::instrument_locks`]),
/// dropping the guard records how long the lock was held — the signal
/// that makes lock contention (a hot shard, a long harvester shrink)
/// visible on the shared metrics plane instead of only as tail latency.
pub struct ShardGuard<'a> {
    guard: MutexGuard<'a, KvStore>,
    held: Option<(Instant, &'a Histogram)>,
}

impl Deref for ShardGuard<'_> {
    type Target = KvStore;
    fn deref(&self) -> &KvStore {
        &self.guard
    }
}

impl DerefMut for ShardGuard<'_> {
    fn deref_mut(&mut self) -> &mut KvStore {
        &mut self.guard
    }
}

impl Drop for ShardGuard<'_> {
    fn drop(&mut self) {
        if let Some((since, hist)) = self.held {
            hist.record_elapsed_us(since);
        }
    }
}

impl ShardedKvStore {
    /// `max_bytes` total budget split across `n_shards` independently
    /// locked shards (clamped to `[1, max_bytes / MIN_SHARD_BYTES]`).
    /// Note the largest storable key+value pair is bounded by one
    /// shard's budget, ~`max_bytes / num_shards()`.
    pub fn new(max_bytes: usize, n_shards: usize, seed: u64) -> Self {
        let n = n_shards.max(1).min((max_bytes / MIN_SHARD_BYTES).max(1));
        let shards = even_split(max_bytes, n)
            .into_iter()
            .enumerate()
            .map(|(i, budget)| {
                let shard_seed = seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                Mutex::new(KvStore::new(budget, shard_seed))
            })
            .collect();
        ShardedKvStore { shards, sample_cursor: AtomicUsize::new(0), lock_hold_us: None }
    }

    /// Record every shard-lock hold time (µs) into `hist`. Called once
    /// at construction time (before the store is shared); uninstrumented
    /// stores pay nothing.
    pub fn instrument_locks(&mut self, hist: Arc<Histogram>) {
        self.lock_hold_us = Some(hist);
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Index of the shard owning `key` (stable for the store's
    /// lifetime). The TCP server's batch path uses it to group a
    /// batch's ops per shard before taking any lock.
    pub fn shard_index(&self, key: &[u8]) -> usize {
        (fnv1a_64(key) % self.shards.len() as u64) as usize
    }

    /// Lock shard `i` directly, exposing the underlying [`KvStore`].
    /// Multi-shard callers (the batch execution path) must acquire in
    /// ascending index order — the same total order `shrink_to` /
    /// `grow_to` use — so no two lock paths can deadlock.
    pub fn lock_shard(&self, i: usize) -> ShardGuard<'_> {
        let guard = self.shards[i].lock().unwrap();
        ShardGuard {
            guard,
            held: self.lock_hold_us.as_deref().map(|h| (Instant::now(), h)),
        }
    }

    fn shard(&self, key: &[u8]) -> ShardGuard<'_> {
        self.lock_shard(self.shard_index(key))
    }

    /// PUT into the owning shard. Returns false when rejected.
    pub fn put(&self, key: &[u8], value: &[u8]) -> bool {
        self.shard(key).put(key, value)
    }

    /// DELETE from the owning shard.
    pub fn delete(&self, key: &[u8]) -> bool {
        self.shard(key).delete(key)
    }

    /// GET, applying `f` to the value borrow *under the shard lock*.
    /// This is the server's zero-copy path: the value is encoded straight
    /// from the store into a caller-owned output buffer, with no
    /// intermediate allocation. Keep `f` cheap — it runs inside the lock.
    pub fn get_with<R>(&self, key: &[u8], f: impl FnOnce(&[u8]) -> R) -> Option<R> {
        self.shard(key).get(key).map(f)
    }

    /// GET into a reusable caller buffer (cleared first); true on hit.
    pub fn get_into(&self, key: &[u8], out: &mut Vec<u8>) -> bool {
        self.shard(key).get_into(key, out)
    }

    /// GET returning an owned copy (tests / non-hot-path callers).
    pub fn get_owned(&self, key: &[u8]) -> Option<Vec<u8>> {
        self.get_with(key, |v| v.to_vec())
    }

    /// Presence + recency bump without reading the value.
    pub fn touch(&self, key: &[u8]) -> bool {
        self.shard(key).touch(key)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn used_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().used_bytes()).sum()
    }

    pub fn live_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().live_bytes()).sum()
    }

    /// Total byte budget (sum of shard budgets).
    pub fn max_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().max_bytes()).sum()
    }

    /// Aggregate fragmentation ratio across shards, 1.0 when empty.
    pub fn fragmentation(&self) -> f64 {
        let (mut used, mut live) = (0usize, 0usize);
        for s in &self.shards {
            let g = s.lock().unwrap();
            used += g.used_bytes();
            live += g.live_bytes();
        }
        if live == 0 {
            1.0
        } else {
            used as f64 / live as f64
        }
    }

    /// Counters summed across all shards.
    pub fn stats(&self) -> KvStats {
        let mut total = KvStats::default();
        for s in &self.shards {
            total.merge(&s.lock().unwrap().stats);
        }
        total
    }

    /// Shard budgets proportional to the current ones, summing exactly to
    /// `new_max` (largest-remainder rounding). Falls back to an even
    /// split when the current total budget is zero — or when any
    /// proportional share rounds to zero: a shard whose budget ever hit
    /// zero would otherwise keep a zero share forever (0 * anything = 0)
    /// and permanently reject its whole key range.
    fn proportional_budgets(guards: &[ShardGuard<'_>], new_max: usize) -> Vec<usize> {
        let n = guards.len();
        let total: usize = guards.iter().map(|g| g.max_bytes()).sum();
        if total == 0 {
            return even_split(new_max, n);
        }
        let mut budgets: Vec<usize> = guards
            .iter()
            .map(|g| ((new_max as u128 * g.max_bytes() as u128) / total as u128) as usize)
            .collect();
        if budgets.iter().any(|&b| b == 0) {
            return even_split(new_max, n);
        }
        // Each floor loses < 1 byte, so the shortfall is < n.
        let mut left = new_max - budgets.iter().sum::<usize>();
        let mut i = 0;
        while left > 0 {
            budgets[i % n] += 1;
            left -= 1;
            i += 1;
        }
        budgets
    }

    /// Harvester-initiated reclaim: shrink the total budget to `new_max`,
    /// distributed proportionally across shards, evicting in each shard
    /// until it fits. Returns total bytes freed. Takes every shard lock
    /// (in index order, the only multi-lock path — no deadlock with the
    /// single-lock request path).
    pub fn shrink_to(&self, new_max: usize) -> usize {
        let mut guards: Vec<ShardGuard<'_>> =
            (0..self.shards.len()).map(|i| self.lock_shard(i)).collect();
        let budgets = Self::proportional_budgets(&guards, new_max);
        guards.iter_mut().zip(budgets).map(|(g, b)| g.shrink_to(b)).sum()
    }

    /// Grow the total budget back toward `new_max`, proportionally per
    /// shard (each shard keeps its budget if already larger).
    pub fn grow_to(&self, new_max: usize) {
        let mut guards: Vec<ShardGuard<'_>> =
            (0..self.shards.len()).map(|i| self.lock_shard(i)).collect();
        let budgets = Self::proportional_budgets(&guards, new_max);
        for (g, b) in guards.iter_mut().zip(budgets) {
            g.grow_to(b);
        }
    }

    /// Defragment every shard; returns total bytes reclaimed.
    pub fn defragment(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().defragment()).sum()
    }

    /// Uniform-ish random resident key: rotates a cursor across shards so
    /// sampling isn't biased to shard 0, then samples within the shard.
    pub fn sample_key(&self) -> Option<Arc<[u8]>> {
        let n = self.shards.len();
        let start = self.sample_cursor.fetch_add(1, Ordering::Relaxed);
        for i in 0..n {
            if let Some(k) = self.shards[(start + i) % n].lock().unwrap().sample_key() {
                return Some(k);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Miri interprets at ~100–1000x cost; the CI `miri` job runs these
    /// tests with reduced counts that keep every assertion structurally
    /// identical.
    const N_KEYS: u32 = if cfg!(miri) { 120 } else { 1000 };

    #[test]
    fn basic_ops_across_shards() {
        let s = ShardedKvStore::new(16 << 20, 8, 1);
        assert_eq!(s.num_shards(), 8);
        for i in 0..N_KEYS {
            assert!(s.put(format!("key{i}").as_bytes(), format!("val{i}").as_bytes()));
        }
        assert_eq!(s.len(), N_KEYS as usize);
        for i in 0..N_KEYS {
            assert_eq!(
                s.get_owned(format!("key{i}").as_bytes()),
                Some(format!("val{i}").into_bytes())
            );
        }
        assert!(s.delete(b"key0"));
        assert!(!s.delete(b"key0"));
        assert_eq!(s.get_owned(b"key0"), None);
        let st = s.stats();
        assert_eq!(st.puts, N_KEYS as u64);
        assert_eq!(st.hits, N_KEYS as u64);
        assert_eq!(st.misses, 1);
        assert_eq!(st.deletes, 1);
    }

    #[test]
    fn keys_spread_over_all_shards() {
        let total: u32 = if cfg!(miri) { 400 } else { 2000 };
        let s = ShardedKvStore::new(16 << 20, 8, 1);
        for i in 0..total {
            s.put(format!("user{i}").as_bytes(), b"v");
        }
        for shard in &s.shards {
            let n = shard.lock().unwrap().len();
            assert!(n > total as usize / 20, "shard imbalance: {n} of {total}");
        }
    }

    #[test]
    fn budgets_sum_exactly() {
        for n in [1, 2, 3, 7, 8, 16] {
            let s = ShardedKvStore::new((64 << 20) + 13, n, 1);
            assert_eq!(s.max_bytes(), (64 << 20) + 13, "n={n}");
            assert_eq!(s.num_shards(), n, "n={n}");
        }
    }

    #[test]
    fn shard_count_clamped_for_small_budgets() {
        // A 2 MB store cannot support 16 shards without collapsing the
        // max storable pair; it gets 2.
        let s = ShardedKvStore::new(2 << 20, 16, 1);
        assert_eq!(s.num_shards(), 2);
        assert_eq!(s.max_bytes(), 2 << 20);
        // Sub-MIN_SHARD_BYTES stores degenerate to a single shard.
        let s = ShardedKvStore::new(64 << 10, 8, 1);
        assert_eq!(s.num_shards(), 1);
        // A pair close to the whole small budget still fits.
        assert!(s.put(b"big", &vec![0u8; 48 << 10]));
    }

    #[test]
    fn shard_index_matches_routing_and_direct_locks_work() {
        let s = ShardedKvStore::new(16 << 20, 8, 1);
        for i in 0..if cfg!(miri) { 64u32 } else { 200 } {
            let key = format!("key{i}");
            s.put(key.as_bytes(), b"v");
            // The shard the router names is the shard that holds it.
            let idx = s.shard_index(key.as_bytes());
            assert!(idx < s.num_shards());
            assert_eq!(s.lock_shard(idx).get(key.as_bytes()), Some(b"v".as_slice()));
        }
        // Ascending multi-lock (the batch path's order) is deadlock-free
        // against itself by construction; smoke it.
        let guards: Vec<_> = (0..s.num_shards()).map(|i| s.lock_shard(i)).collect();
        assert_eq!(guards.len(), 8);
    }

    #[test]
    fn get_with_runs_under_lock_and_returns_closure_result() {
        let s = ShardedKvStore::new(1 << 20, 4, 1);
        s.put(b"k", &[1, 2, 3]);
        assert_eq!(s.get_with(b"k", |v| v.len()), Some(3));
        assert_eq!(s.get_with(b"absent", |v| v.len()), None);
        let mut out = Vec::new();
        assert!(s.get_into(b"k", &mut out));
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn shrink_to_is_cross_shard_and_exact() {
        let s = ShardedKvStore::new(4 << 20, 4, 7);
        // Same ~2.7 MB of payload either way; Miri gets it in fewer,
        // larger pairs.
        let (n, val_bytes) = if cfg!(miri) { (300u32, 9000) } else { (3000, 900) };
        for i in 0..n {
            s.put(format!("k{i}").as_bytes(), &vec![1u8; val_bytes]);
        }
        let used = s.used_bytes();
        let freed = s.shrink_to(1 << 20);
        assert_eq!(s.max_bytes(), 1 << 20, "shard budgets must sum to the new max");
        assert!(s.used_bytes() <= 1 << 20);
        assert_eq!(freed, used - s.used_bytes());
        s.grow_to(4 << 20);
        assert_eq!(s.max_bytes(), 4 << 20);
    }

    #[test]
    fn shards_recover_budget_after_extreme_shrink() {
        let s = ShardedKvStore::new(16 << 20, 8, 1);
        // Sub-n_shards budget: some shards necessarily drop to zero.
        s.shrink_to(4);
        assert_eq!(s.max_bytes(), 4);
        // Growing back must not leave zero-budget shards stranded.
        s.grow_to(16 << 20);
        assert_eq!(s.max_bytes(), 16 << 20);
        for i in 0..100u32 {
            assert!(s.put(format!("k{i}").as_bytes(), b"v"), "shard stuck at zero budget");
        }
        assert_eq!(s.len(), 100);
    }

    #[test]
    fn defragment_aggregates() {
        let s = ShardedKvStore::new(16 << 20, 4, 9);
        for i in 0..500u32 {
            s.put(format!("k{i}").as_bytes(), &vec![0u8; 150]);
        }
        assert!(s.fragmentation() > 1.0);
        assert!(s.defragment() > 0);
        assert!((s.fragmentation() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn lock_hold_histogram_records_on_instrumented_stores() {
        let mut s = ShardedKvStore::new(4 << 20, 4, 1);
        let hist = Arc::new(Histogram::new());
        s.instrument_locks(hist.clone());
        s.put(b"k", b"v");
        assert_eq!(s.get_owned(b"k"), Some(b"v".to_vec()));
        s.shrink_to(1 << 20); // takes all 4 shard locks
        let n = hist.snapshot().count();
        assert!(n >= 6, "lock holds not recorded: {n}");
        // Uninstrumented stores record nothing (and pay nothing).
        let s2 = ShardedKvStore::new(4 << 20, 4, 1);
        s2.put(b"k", b"v");
        assert_eq!(hist.snapshot().count(), n);
    }

    #[test]
    fn sample_key_finds_resident_keys() {
        let s = ShardedKvStore::new(1 << 20, 4, 3);
        assert!(s.sample_key().is_none());
        s.put(b"only", b"v");
        for _ in 0..16 {
            assert_eq!(s.sample_key().unwrap().as_ref(), b"only");
        }
    }
}

//! Byte-accounted KV store with sampled approximate-LRU eviction.
//!
//! Allocation discipline (the consumer GET/PUT hot path, paper §4.2):
//! each key's bytes are stored exactly once in a shared `Arc<[u8]>`
//! referenced by both the map and the sampling vector; a GET hit returns
//! a borrow (no value clone); overwrites reuse the existing value
//! buffer; and eviction sampling never copies key bytes.

use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::Arc;

/// Per-entry bookkeeping overhead, approximating Redis's dictEntry +
/// robj + SDS headers (~64 bytes).
pub const ENTRY_OVERHEAD: usize = 64;

/// How many random keys an eviction samples (Redis `maxmemory-samples`).
pub const EVICTION_SAMPLES: usize = 5;

#[derive(Clone, Debug, Default)]
pub struct KvStats {
    pub hits: u64,
    pub misses: u64,
    pub puts: u64,
    pub deletes: u64,
    pub evictions: u64,
    pub rejected: u64,
}

impl KvStats {
    /// Accumulate another store's counters (shard aggregation).
    pub fn merge(&mut self, other: &KvStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.puts += other.puts;
        self.deletes += other.deletes;
        self.evictions += other.evictions;
        self.rejected += other.rejected;
    }
}

impl crate::metrics::Observe for KvStats {
    fn observe(&self, prefix: &str, out: &mut crate::metrics::MetricSet) {
        use crate::metrics::scoped;
        out.set_counter(scoped(prefix, "hits"), self.hits);
        out.set_counter(scoped(prefix, "misses"), self.misses);
        out.set_counter(scoped(prefix, "puts"), self.puts);
        out.set_counter(scoped(prefix, "deletes"), self.deletes);
        out.set_counter(scoped(prefix, "evictions"), self.evictions);
        out.set_counter(scoped(prefix, "rejected"), self.rejected);
    }
}

struct Entry {
    value: Vec<u8>,
    /// Logical LRU clock value at last access.
    last_access: u64,
    /// Bytes charged to this entry (size-class rounded).
    alloc: usize,
    /// Position in `keys` for O(1) random sampling.
    key_index: usize,
}

/// Round an allocation up to its size class (jemalloc-style: 8-byte steps
/// to 128, then 1/8th-spaced classes). This models the internal
/// fragmentation the paper's defragmentation discussion concerns.
fn size_class(n: usize) -> usize {
    if n <= 8 {
        return 8;
    }
    if n <= 128 {
        return (n + 7) & !7;
    }
    // Classes at lo + k*(lo/8) within each power-of-two range.
    let pow = usize::BITS - (n - 1).leading_zeros(); // ceil log2
    let lo = 1usize << (pow - 1);
    let step = (lo / 8).max(8);
    lo + (n - lo).div_ceil(step) * step
}

/// A single producer store: one per consumer lease (paper §4.2).
/// Inside [`crate::kv::ShardedKvStore`], one of these backs each shard.
pub struct KvStore {
    map: HashMap<Arc<[u8]>, Entry>,
    /// All keys, for O(1) uniform sampling (Redis-style eviction pool).
    /// Shares the `Arc<[u8]>` allocations with `map`: key bytes are
    /// stored once.
    keys: Vec<Arc<[u8]>>,
    max_bytes: usize,
    used_bytes: usize,
    /// Bytes actually used by live data (<= used_bytes; difference is
    /// internal fragmentation that `defragment` can reclaim).
    live_bytes: usize,
    clock: u64,
    rng: Rng,
    pub stats: KvStats,
}

impl KvStore {
    pub fn new(max_bytes: usize, seed: u64) -> Self {
        KvStore {
            map: HashMap::new(),
            keys: Vec::new(),
            max_bytes,
            used_bytes: 0,
            live_bytes: 0,
            clock: 0,
            rng: Rng::new(seed),
            stats: KvStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }
    pub fn live_bytes(&self) -> usize {
        self.live_bytes
    }
    pub fn max_bytes(&self) -> usize {
        self.max_bytes
    }

    /// Fragmentation ratio (allocated / live), 1.0 when empty.
    pub fn fragmentation(&self) -> f64 {
        if self.live_bytes == 0 {
            1.0
        } else {
            self.used_bytes as f64 / self.live_bytes as f64
        }
    }

    fn charge(key: &[u8], value: &[u8]) -> (usize, usize) {
        let live = key.len() + value.len() + ENTRY_OVERHEAD;
        (size_class(live), live)
    }

    /// The shared GET core: advance the clock, bump recency on a hit,
    /// and account hit/miss stats exactly once for all access variants.
    fn lookup_hit(&mut self, key: &[u8]) -> Option<&mut Entry> {
        self.clock += 1;
        match self.map.get_mut(key) {
            Some(e) => {
                e.last_access = self.clock;
                self.stats.hits += 1;
                Some(e)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// GET: borrows the value and bumps LRU recency. A steady-state hit
    /// performs no clone; callers that need ownership use
    /// [`Self::get_into`] or copy explicitly.
    pub fn get(&mut self, key: &[u8]) -> Option<&[u8]> {
        self.lookup_hit(key).map(|e| e.value.as_slice())
    }

    /// GET into a caller-owned buffer (cleared first, capacity reused
    /// across calls). Returns true on a hit.
    pub fn get_into(&mut self, key: &[u8], out: &mut Vec<u8>) -> bool {
        match self.lookup_hit(key) {
            Some(e) => {
                out.clear();
                out.extend_from_slice(&e.value);
                true
            }
            None => false,
        }
    }

    /// Presence check that bumps recency (and hit/miss stats) without
    /// touching the value bytes at all.
    pub fn touch(&mut self, key: &[u8]) -> bool {
        self.lookup_hit(key).is_some()
    }

    /// PUT: inserts/overwrites, evicting LRU-approximate victims if needed.
    /// Returns false (rejecting the write) when the pair can never fit.
    /// Overwrites reuse the entry's value buffer; a fresh insert stores
    /// the key bytes once, shared between the map and the sampling vec.
    pub fn put(&mut self, key: &[u8], value: &[u8]) -> bool {
        let (alloc, live) = Self::charge(key, value);
        if alloc > self.max_bytes {
            self.stats.rejected += 1;
            return false;
        }
        self.clock += 1;
        // Replace in place if present.
        if let Some(e) = self.map.get_mut(key) {
            let (old_alloc, old_live) = (e.alloc, e.value.len() + key.len() + ENTRY_OVERHEAD);
            e.value.clear();
            e.value.extend_from_slice(value);
            // Reuse the buffer for same-sized overwrites, but don't let a
            // once-large value pin its peak capacity forever: the byte
            // accounting reports `alloc` to the harvester, so real heap
            // slack must stay bounded (<= 2x the live size).
            if e.value.capacity() / 2 > e.value.len().max(32) {
                e.value.shrink_to_fit();
            }
            e.alloc = alloc;
            e.last_access = self.clock;
            self.used_bytes = self.used_bytes - old_alloc + alloc;
            self.live_bytes = self.live_bytes - old_live + live;
        } else {
            let key_arc: Arc<[u8]> = Arc::from(key);
            let key_index = self.keys.len();
            self.keys.push(Arc::clone(&key_arc));
            self.map.insert(
                key_arc,
                Entry { value: value.to_vec(), last_access: self.clock, alloc, key_index },
            );
            self.used_bytes += alloc;
            self.live_bytes += live;
        }
        self.stats.puts += 1;
        while self.used_bytes > self.max_bytes {
            if !self.evict_one() {
                break;
            }
        }
        true
    }

    /// DELETE: explicit consumer-side removal (paper §6.1).
    pub fn delete(&mut self, key: &[u8]) -> bool {
        let removed = self.remove_entry(key);
        if removed {
            self.stats.deletes += 1;
        }
        removed
    }

    fn remove_entry(&mut self, key: &[u8]) -> bool {
        if let Some(e) = self.map.remove(key) {
            self.used_bytes -= e.alloc;
            self.live_bytes -= e.value.len() + key.len() + ENTRY_OVERHEAD;
            // swap-remove from the sampling vec, fixing the moved key's
            // index (an Arc refcount bump, not a byte copy).
            let idx = e.key_index;
            self.keys.swap_remove(idx);
            if idx < self.keys.len() {
                let moved = Arc::clone(&self.keys[idx]);
                self.map.get_mut(moved.as_ref()).expect("moved key present").key_index = idx;
            }
            true
        } else {
            false
        }
    }

    /// Evict one victim via Redis-style sampling: pick
    /// `EVICTION_SAMPLES` random keys, evict the least recently used.
    /// Clone-free: victim selection reads through the shared key Arcs.
    fn evict_one(&mut self) -> bool {
        if self.keys.is_empty() {
            return false;
        }
        let mut victim: Option<(u64, usize)> = None;
        for _ in 0..EVICTION_SAMPLES.min(self.keys.len()) {
            let i = self.rng.below(self.keys.len() as u64) as usize;
            let e = &self.map[self.keys[i].as_ref()];
            if victim.map_or(true, |(age, _)| e.last_access < age) {
                victim = Some((e.last_access, i));
            }
        }
        let (_, idx) = victim.expect("non-empty sampled");
        let key = Arc::clone(&self.keys[idx]);
        self.remove_entry(&key);
        self.stats.evictions += 1;
        true
    }

    /// Harvester-initiated reclaim (paper §4.2 "Eviction"): shrink the
    /// budget and evict until under the new limit. Returns bytes freed.
    pub fn shrink_to(&mut self, new_max: usize) -> usize {
        let before = self.used_bytes;
        self.max_bytes = new_max;
        while self.used_bytes > self.max_bytes {
            if !self.evict_one() {
                break;
            }
        }
        before - self.used_bytes
    }

    /// Grow the budget back (lease extension / recovery ended).
    pub fn grow_to(&mut self, new_max: usize) {
        self.max_bytes = self.max_bytes.max(new_max);
    }

    /// Defragment: compact allocations down to live bytes (Redis
    /// activedefrag). Returns bytes reclaimed.
    pub fn defragment(&mut self) -> usize {
        // After compaction every entry is charged exactly its live size.
        let mut new_used = 0usize;
        for (k, e) in self.map.iter_mut() {
            let live = k.len() + e.value.len() + ENTRY_OVERHEAD;
            e.alloc = live;
            new_used += live;
        }
        let freed = self.used_bytes.saturating_sub(new_used);
        self.used_bytes = new_used;
        freed
    }

    /// Uniform random resident key (for workload-driven scans/tests).
    /// Returns a shared handle to the key bytes (refcount bump only).
    pub fn sample_key(&mut self) -> Option<Arc<[u8]>> {
        if self.keys.is_empty() {
            None
        } else {
            let i = self.rng.below(self.keys.len() as u64) as usize;
            Some(Arc::clone(&self.keys[i]))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_classes_monotone_and_cover() {
        let mut prev = 0;
        for n in 1..5000 {
            let c = size_class(n);
            assert!(c >= n, "class {c} < size {n}");
            assert!(c >= prev || c >= size_class(n - 1), "non-monotone at {n}");
            prev = c;
        }
        assert_eq!(size_class(8), 8);
        assert_eq!(size_class(9), 16);
        assert_eq!(size_class(128), 128);
    }

    #[test]
    fn put_get_delete() {
        let mut kv = KvStore::new(1 << 20, 1);
        assert!(kv.put(b"k1", b"v1"));
        assert_eq!(kv.get(b"k1"), Some(&b"v1"[..]));
        assert_eq!(kv.get(b"nope"), None);
        assert!(kv.delete(b"k1"));
        assert!(!kv.delete(b"k1"));
        assert_eq!(kv.get(b"k1"), None);
        assert_eq!(kv.stats.hits, 1);
        assert_eq!(kv.stats.misses, 2);
        assert_eq!(kv.stats.deletes, 1);
        assert!(kv.is_empty());
        assert_eq!(kv.used_bytes(), 0);
        assert_eq!(kv.live_bytes(), 0);
    }

    #[test]
    fn get_into_reuses_buffer() {
        let mut kv = KvStore::new(1 << 20, 1);
        kv.put(b"k", &vec![7u8; 1000]);
        let mut buf = Vec::new();
        assert!(kv.get_into(b"k", &mut buf));
        assert_eq!(buf.len(), 1000);
        let cap = buf.capacity();
        for _ in 0..100 {
            assert!(kv.get_into(b"k", &mut buf));
        }
        assert_eq!(buf.capacity(), cap, "get_into reallocated a reused buffer");
        assert!(!kv.get_into(b"absent", &mut buf));
    }

    #[test]
    fn key_bytes_stored_once() {
        let mut kv = KvStore::new(1 << 20, 1);
        kv.put(b"only-key", b"v");
        let k = kv.sample_key().unwrap();
        // map + keys vec + our local handle = 3 owners of ONE allocation.
        assert_eq!(Arc::strong_count(&k), 3);
    }

    #[test]
    fn touch_bumps_recency_without_reading() {
        let mut kv = KvStore::new(1 << 20, 1);
        kv.put(b"k", b"v");
        assert!(kv.touch(b"k"));
        assert!(!kv.touch(b"absent"));
        assert_eq!(kv.stats.hits, 1);
        assert_eq!(kv.stats.misses, 1);
    }

    #[test]
    fn overwrite_accounting_exact() {
        let mut kv = KvStore::new(1 << 20, 1);
        kv.put(b"k", &vec![0u8; 100]);
        let used_100 = kv.used_bytes();
        kv.put(b"k", &vec![0u8; 500]);
        kv.put(b"k", &vec![0u8; 100]);
        assert_eq!(kv.used_bytes(), used_100);
        assert_eq!(kv.len(), 1);
    }

    #[test]
    fn eviction_respects_limit_and_prefers_cold() {
        let mut kv = KvStore::new(64 * 1024, 42);
        // Insert 1 KB values until well past the limit.
        for i in 0..200u32 {
            kv.put(format!("key{i}").as_bytes(), &vec![1u8; 1024]);
        }
        assert!(kv.used_bytes() <= kv.max_bytes());
        assert!(kv.stats.evictions > 0);
        // Keep key0 hot while flooding: it should survive.
        let mut kv = KvStore::new(64 * 1024, 43);
        kv.put(b"hot", &vec![1u8; 1024]);
        for i in 0..500u32 {
            let _ = kv.get(b"hot");
            kv.put(format!("cold{i}").as_bytes(), &vec![1u8; 1024]);
        }
        assert!(kv.get(b"hot").is_some(), "hot key evicted by approx-LRU");
    }

    #[test]
    fn rejects_oversized() {
        let mut kv = KvStore::new(1024, 1);
        assert!(!kv.put(b"big", &vec![0u8; 4096]));
        assert_eq!(kv.stats.rejected, 1);
        assert!(kv.is_empty());
    }

    #[test]
    fn shrink_evicts_and_reports() {
        let mut kv = KvStore::new(1 << 20, 7);
        for i in 0..100u32 {
            kv.put(format!("k{i}").as_bytes(), &vec![0u8; 2048]);
        }
        let before = kv.used_bytes();
        let freed = kv.shrink_to(before / 2);
        assert!(freed > 0);
        assert!(kv.used_bytes() <= before / 2);
        kv.grow_to(1 << 20);
        assert_eq!(kv.max_bytes(), 1 << 20);
    }

    #[test]
    fn defragment_reclaims_class_waste() {
        let mut kv = KvStore::new(1 << 20, 9);
        // 200-byte live entries land in a larger size class.
        for i in 0..50u32 {
            kv.put(format!("k{i}").as_bytes(), &vec![0u8; 150]);
        }
        assert!(kv.fragmentation() > 1.0);
        let freed = kv.defragment();
        assert!(freed > 0);
        assert!((kv.fragmentation() - 1.0).abs() < 1e-9);
        // Data intact.
        assert_eq!(kv.get(b"k0").unwrap().len(), 150);
    }

    #[test]
    fn accounting_invariant_random_ops() {
        let mut kv = KvStore::new(256 * 1024, 11);
        let mut rng = Rng::new(5);
        for step in 0..20_000u64 {
            let k = format!("key{}", rng.below(500));
            match rng.below(10) {
                0..=5 => {
                    kv.put(k.as_bytes(), &vec![0u8; rng.below(2000) as usize + 1]);
                }
                6..=8 => {
                    let _ = kv.get(k.as_bytes());
                }
                _ => {
                    let _ = kv.delete(k.as_bytes());
                }
            }
            assert!(kv.used_bytes() <= kv.max_bytes(), "step {step}");
            assert!(kv.live_bytes() <= kv.used_bytes(), "step {step}");
        }
        // Delete everything: accounting must return to zero.
        let keys: Vec<Vec<u8>> = (0..500).map(|i| format!("key{i}").into_bytes()).collect();
        for k in keys {
            let _ = kv.delete(&k);
        }
        assert_eq!(kv.used_bytes(), 0);
        assert_eq!(kv.live_bytes(), 0);
        assert_eq!(kv.len(), 0);
    }
}

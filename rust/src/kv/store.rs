//! Byte-accounted KV store with sampled approximate-LRU eviction.

use crate::util::rng::Rng;
use std::collections::HashMap;

/// Per-entry bookkeeping overhead, approximating Redis's dictEntry +
/// robj + SDS headers (~64 bytes).
pub const ENTRY_OVERHEAD: usize = 64;

/// How many random keys an eviction samples (Redis `maxmemory-samples`).
pub const EVICTION_SAMPLES: usize = 5;

#[derive(Clone, Debug, Default)]
pub struct KvStats {
    pub hits: u64,
    pub misses: u64,
    pub puts: u64,
    pub deletes: u64,
    pub evictions: u64,
    pub rejected: u64,
}

struct Entry {
    value: Vec<u8>,
    /// Logical LRU clock value at last access.
    last_access: u64,
    /// Bytes charged to this entry (size-class rounded).
    alloc: usize,
    /// Position in `keys` for O(1) random sampling.
    key_index: usize,
}

/// Round an allocation up to its size class (jemalloc-style: 8-byte steps
/// to 128, then 1/8th-spaced classes). This models the internal
/// fragmentation the paper's defragmentation discussion concerns.
fn size_class(n: usize) -> usize {
    if n <= 8 {
        return 8;
    }
    if n <= 128 {
        return (n + 7) & !7;
    }
    // Classes at lo + k*(lo/8) within each power-of-two range.
    let pow = usize::BITS - (n - 1).leading_zeros(); // ceil log2
    let lo = 1usize << (pow - 1);
    let step = (lo / 8).max(8);
    lo + (n - lo).div_ceil(step) * step
}

/// A single producer store: one per consumer lease (paper §4.2).
pub struct KvStore {
    map: HashMap<Vec<u8>, Entry>,
    /// All keys, for O(1) uniform sampling (Redis-style eviction pool).
    keys: Vec<Vec<u8>>,
    max_bytes: usize,
    used_bytes: usize,
    /// Bytes actually used by live data (<= used_bytes; difference is
    /// internal fragmentation that `defragment` can reclaim).
    live_bytes: usize,
    clock: u64,
    rng: Rng,
    pub stats: KvStats,
}

impl KvStore {
    pub fn new(max_bytes: usize, seed: u64) -> Self {
        KvStore {
            map: HashMap::new(),
            keys: Vec::new(),
            max_bytes,
            used_bytes: 0,
            live_bytes: 0,
            clock: 0,
            rng: Rng::new(seed),
            stats: KvStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }
    pub fn live_bytes(&self) -> usize {
        self.live_bytes
    }
    pub fn max_bytes(&self) -> usize {
        self.max_bytes
    }

    /// Fragmentation ratio (allocated / live), 1.0 when empty.
    pub fn fragmentation(&self) -> f64 {
        if self.live_bytes == 0 {
            1.0
        } else {
            self.used_bytes as f64 / self.live_bytes as f64
        }
    }

    fn charge(key: &[u8], value: &[u8]) -> (usize, usize) {
        let live = key.len() + value.len() + ENTRY_OVERHEAD;
        (size_class(live), live)
    }

    /// GET: returns the value and bumps LRU recency.
    pub fn get(&mut self, key: &[u8]) -> Option<Vec<u8>> {
        self.clock += 1;
        match self.map.get_mut(key) {
            Some(e) => {
                e.last_access = self.clock;
                self.stats.hits += 1;
                Some(e.value.clone())
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// PUT: inserts/overwrites, evicting LRU-approximate victims if needed.
    /// Returns false (rejecting the write) when the pair can never fit.
    pub fn put(&mut self, key: &[u8], value: &[u8]) -> bool {
        let (alloc, live) = Self::charge(key, value);
        if alloc > self.max_bytes {
            self.stats.rejected += 1;
            return false;
        }
        self.clock += 1;
        // Replace in place if present.
        if let Some(e) = self.map.get_mut(key) {
            let (old_alloc, old_live) = (e.alloc, e.value.len() + key.len() + ENTRY_OVERHEAD);
            e.value = value.to_vec();
            e.alloc = alloc;
            e.last_access = self.clock;
            self.used_bytes = self.used_bytes - old_alloc + alloc;
            self.live_bytes = self.live_bytes - old_live + live;
        } else {
            let key_index = self.keys.len();
            self.keys.push(key.to_vec());
            self.map.insert(
                key.to_vec(),
                Entry { value: value.to_vec(), last_access: self.clock, alloc, key_index },
            );
            self.used_bytes += alloc;
            self.live_bytes += live;
        }
        self.stats.puts += 1;
        while self.used_bytes > self.max_bytes {
            if !self.evict_one() {
                break;
            }
        }
        true
    }

    /// DELETE: explicit consumer-side removal (paper §6.1).
    pub fn delete(&mut self, key: &[u8]) -> bool {
        let removed = self.remove_entry(key);
        if removed {
            self.stats.deletes += 1;
        }
        removed
    }

    fn remove_entry(&mut self, key: &[u8]) -> bool {
        if let Some(e) = self.map.remove(key) {
            self.used_bytes -= e.alloc;
            self.live_bytes -= e.value.len() + key.len() + ENTRY_OVERHEAD;
            // swap-remove from the sampling vec, fixing the moved key's index
            let idx = e.key_index;
            self.keys.swap_remove(idx);
            if idx < self.keys.len() {
                let moved = self.keys[idx].clone();
                self.map.get_mut(&moved).expect("moved key present").key_index = idx;
            }
            true
        } else {
            false
        }
    }

    /// Evict one victim via Redis-style sampling: pick
    /// `EVICTION_SAMPLES` random keys, evict the least recently used.
    fn evict_one(&mut self) -> bool {
        if self.keys.is_empty() {
            return false;
        }
        let mut victim: Option<(u64, usize)> = None;
        for _ in 0..EVICTION_SAMPLES.min(self.keys.len()) {
            let i = self.rng.below(self.keys.len() as u64) as usize;
            let e = &self.map[&self.keys[i]];
            if victim.map_or(true, |(age, _)| e.last_access < age) {
                victim = Some((e.last_access, i));
            }
        }
        let (_, idx) = victim.expect("non-empty sampled");
        let key = self.keys[idx].clone();
        self.remove_entry(&key);
        self.stats.evictions += 1;
        true
    }

    /// Harvester-initiated reclaim (paper §4.2 "Eviction"): shrink the
    /// budget and evict until under the new limit. Returns bytes freed.
    pub fn shrink_to(&mut self, new_max: usize) -> usize {
        let before = self.used_bytes;
        self.max_bytes = new_max;
        while self.used_bytes > self.max_bytes {
            if !self.evict_one() {
                break;
            }
        }
        before - self.used_bytes
    }

    /// Grow the budget back (lease extension / recovery ended).
    pub fn grow_to(&mut self, new_max: usize) {
        self.max_bytes = self.max_bytes.max(new_max);
    }

    /// Defragment: compact allocations down to live bytes (Redis
    /// activedefrag). Returns bytes reclaimed.
    pub fn defragment(&mut self) -> usize {
        // After compaction every entry is charged exactly its live size.
        let mut new_used = 0usize;
        for (k, e) in self.map.iter_mut() {
            let live = k.len() + e.value.len() + ENTRY_OVERHEAD;
            e.alloc = live;
            new_used += live;
        }
        let freed = self.used_bytes.saturating_sub(new_used);
        self.used_bytes = new_used;
        freed
    }

    /// Uniform random resident key (for workload-driven scans/tests).
    pub fn sample_key(&mut self) -> Option<Vec<u8>> {
        if self.keys.is_empty() {
            None
        } else {
            let i = self.rng.below(self.keys.len() as u64) as usize;
            Some(self.keys[i].clone())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_classes_monotone_and_cover() {
        let mut prev = 0;
        for n in 1..5000 {
            let c = size_class(n);
            assert!(c >= n, "class {c} < size {n}");
            assert!(c >= prev || c >= size_class(n - 1), "non-monotone at {n}");
            prev = c;
        }
        assert_eq!(size_class(8), 8);
        assert_eq!(size_class(9), 16);
        assert_eq!(size_class(128), 128);
    }

    #[test]
    fn put_get_delete() {
        let mut kv = KvStore::new(1 << 20, 1);
        assert!(kv.put(b"k1", b"v1"));
        assert_eq!(kv.get(b"k1"), Some(b"v1".to_vec()));
        assert_eq!(kv.get(b"nope"), None);
        assert!(kv.delete(b"k1"));
        assert!(!kv.delete(b"k1"));
        assert_eq!(kv.get(b"k1"), None);
        assert_eq!(kv.stats.hits, 1);
        assert_eq!(kv.stats.misses, 2);
        assert_eq!(kv.stats.deletes, 1);
        assert!(kv.is_empty());
        assert_eq!(kv.used_bytes(), 0);
        assert_eq!(kv.live_bytes(), 0);
    }

    #[test]
    fn overwrite_accounting_exact() {
        let mut kv = KvStore::new(1 << 20, 1);
        kv.put(b"k", &vec![0u8; 100]);
        let used_100 = kv.used_bytes();
        kv.put(b"k", &vec![0u8; 500]);
        kv.put(b"k", &vec![0u8; 100]);
        assert_eq!(kv.used_bytes(), used_100);
        assert_eq!(kv.len(), 1);
    }

    #[test]
    fn eviction_respects_limit_and_prefers_cold() {
        let mut kv = KvStore::new(64 * 1024, 42);
        // Insert 1 KB values until well past the limit.
        for i in 0..200u32 {
            kv.put(format!("key{i}").as_bytes(), &vec![1u8; 1024]);
        }
        assert!(kv.used_bytes() <= kv.max_bytes());
        assert!(kv.stats.evictions > 0);
        // Keep key0 hot while flooding: it should survive.
        let mut kv = KvStore::new(64 * 1024, 43);
        kv.put(b"hot", &vec![1u8; 1024]);
        for i in 0..500u32 {
            let _ = kv.get(b"hot");
            kv.put(format!("cold{i}").as_bytes(), &vec![1u8; 1024]);
        }
        assert!(kv.get(b"hot").is_some(), "hot key evicted by approx-LRU");
    }

    #[test]
    fn rejects_oversized() {
        let mut kv = KvStore::new(1024, 1);
        assert!(!kv.put(b"big", &vec![0u8; 4096]));
        assert_eq!(kv.stats.rejected, 1);
        assert!(kv.is_empty());
    }

    #[test]
    fn shrink_evicts_and_reports() {
        let mut kv = KvStore::new(1 << 20, 7);
        for i in 0..100u32 {
            kv.put(format!("k{i}").as_bytes(), &vec![0u8; 2048]);
        }
        let before = kv.used_bytes();
        let freed = kv.shrink_to(before / 2);
        assert!(freed > 0);
        assert!(kv.used_bytes() <= before / 2);
        kv.grow_to(1 << 20);
        assert_eq!(kv.max_bytes(), 1 << 20);
    }

    #[test]
    fn defragment_reclaims_class_waste() {
        let mut kv = KvStore::new(1 << 20, 9);
        // 200-byte live entries land in a larger size class.
        for i in 0..50u32 {
            kv.put(format!("k{i}").as_bytes(), &vec![0u8; 150]);
        }
        assert!(kv.fragmentation() > 1.0);
        let freed = kv.defragment();
        assert!(freed > 0);
        assert!((kv.fragmentation() - 1.0).abs() < 1e-9);
        // Data intact.
        assert_eq!(kv.get(b"k0").unwrap().len(), 150);
    }

    #[test]
    fn accounting_invariant_random_ops() {
        let mut kv = KvStore::new(256 * 1024, 11);
        let mut rng = Rng::new(5);
        for step in 0..20_000u64 {
            let k = format!("key{}", rng.below(500));
            match rng.below(10) {
                0..=5 => {
                    kv.put(k.as_bytes(), &vec![0u8; rng.below(2000) as usize + 1]);
                }
                6..=8 => {
                    let _ = kv.get(k.as_bytes());
                }
                _ => {
                    let _ = kv.delete(k.as_bytes());
                }
            }
            assert!(kv.used_bytes() <= kv.max_bytes(), "step {step}");
            assert!(kv.live_bytes() <= kv.used_bytes(), "step {step}");
        }
        // Delete everything: accounting must return to zero.
        let keys: Vec<Vec<u8>> = (0..500).map(|i| format!("key{i}").into_bytes()).collect();
        for k in keys {
            let _ = kv.delete(&k);
        }
        assert_eq!(kv.used_bytes(), 0);
        assert_eq!(kv.live_bytes(), 0);
        assert_eq!(kv.len(), 0);
    }
}

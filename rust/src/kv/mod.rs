//! Redis-like key-value store — the producer-store substrate (paper §4.2).
//!
//! The paper runs one Redis server per consumer inside a cgroup. We build
//! the equivalent from scratch: a byte-accounted KV store with Redis's
//! sampled approximate-LRU eviction [Psounis et al.], an explicit
//! `evict_bytes` path for harvester-initiated reclaims, a size-class
//! allocation model whose external fragmentation can be compacted via
//! `defragment` (Redis "activedefrag"), and hit/miss/eviction statistics.

pub mod store;

pub use store::{KvStats, KvStore};

//! Redis-like key-value store — the producer-store substrate (paper §4.2).
//!
//! The paper runs one Redis server per consumer inside a cgroup. We build
//! the equivalent from scratch: a byte-accounted KV store with Redis's
//! sampled approximate-LRU eviction [Psounis et al.], an explicit
//! `evict_bytes` path for harvester-initiated reclaims, a size-class
//! allocation model whose external fragmentation can be compacted via
//! `defragment` (Redis "activedefrag"), and hit/miss/eviction statistics.
//!
//! Two layers: [`KvStore`] is the single-threaded core (one per shard,
//! or standalone in the simulator); [`ShardedKvStore`] hash-partitions
//! keys across N independently locked shards so the TCP server's
//! connection threads never serialize on one global mutex.

pub mod sharded;
pub mod store;

pub use sharded::{ShardGuard, ShardedKvStore};
pub use store::{KvStats, KvStore};

//! Multi-threaded hammer tests for the sharded producer store: byte
//! accounting must stay consistent under concurrent GET/PUT/DELETE from
//! many threads, return exactly to zero after a full delete, and the
//! cross-shard budget operations must distribute exactly.

use memtrade::kv::ShardedKvStore;
use memtrade::net::tcp::{KvClient, ProducerStoreServer};
use memtrade::util::rng::Rng;
use std::sync::Arc;

/// Shared key space: every thread draws from the same 4x800 keys so
/// shards see real cross-thread contention, not private partitions.
fn hammer_key(rng: &mut Rng) -> String {
    format!("t{}k{}", rng.below(4), rng.below(800))
}

#[test]
fn hammer_accounting_invariants_under_concurrency() {
    let store = Arc::new(ShardedKvStore::new(8 << 20, 8, 42));
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let store = store.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(1000 + t);
                let mut buf = Vec::with_capacity(2048);
                for _ in 0..20_000 {
                    let k = hammer_key(&mut rng);
                    match rng.below(10) {
                        0..=5 => {
                            store.put(k.as_bytes(), &vec![0u8; 1 + rng.below(1500) as usize]);
                        }
                        6..=8 => {
                            let _ = store.get_into(k.as_bytes(), &mut buf);
                        }
                        _ => {
                            let _ = store.delete(k.as_bytes());
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // Aggregate invariants after the storm.
    assert!(store.used_bytes() <= store.max_bytes());
    assert!(store.live_bytes() <= store.used_bytes());
    let stats = store.stats();
    assert!(stats.puts > 0 && stats.hits > 0 && stats.deletes > 0);

    // Delete every possible key: accounting must return exactly to zero
    // across all shards.
    for t in 0..4u64 {
        for i in 0..800u64 {
            let _ = store.delete(format!("t{t}k{i}").as_bytes());
        }
    }
    assert_eq!(store.len(), 0);
    assert_eq!(store.used_bytes(), 0);
    assert_eq!(store.live_bytes(), 0);
    assert!((store.fragmentation() - 1.0).abs() < 1e-12);
}

#[test]
fn concurrent_readers_see_consistent_values() {
    // Writers continuously overwrite whole-value patterns; readers must
    // never observe a torn mix (each value is byte-uniform).
    let store = Arc::new(ShardedKvStore::new(64 << 20, 8, 7));
    for i in 0..64u32 {
        store.put(format!("k{i}").as_bytes(), &vec![0u8; 512]);
    }
    let writers: Vec<_> = (0..2)
        .map(|t| {
            let store = store.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(50 + t);
                for _ in 0..30_000 {
                    let i = rng.below(64);
                    let fill = rng.below(256) as u8;
                    store.put(format!("k{i}").as_bytes(), &vec![fill; 512]);
                }
            })
        })
        .collect();
    let readers: Vec<_> = (0..4)
        .map(|t| {
            let store = store.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(80 + t);
                let mut buf = Vec::with_capacity(1024);
                for _ in 0..30_000 {
                    let i = rng.below(64);
                    if store.get_into(format!("k{i}").as_bytes(), &mut buf) {
                        assert_eq!(buf.len(), 512);
                        let first = buf[0];
                        assert!(buf.iter().all(|&b| b == first), "torn read");
                    }
                }
            })
        })
        .collect();
    for h in writers.into_iter().chain(readers) {
        h.join().unwrap();
    }
}

#[test]
fn cross_shard_shrink_is_exact_and_proportional() {
    let store = ShardedKvStore::new(4 << 20, 4, 11);
    for i in 0..3000u32 {
        store.put(format!("k{i}").as_bytes(), &vec![1u8; 900]);
    }
    let used_before = store.used_bytes();
    assert!(used_before > 1 << 20);

    let freed = store.shrink_to(1 << 20);
    // Budgets sum exactly to the new max, and eviction honored it.
    assert_eq!(store.max_bytes(), 1 << 20);
    assert!(store.used_bytes() <= 1 << 20);
    assert_eq!(freed, used_before - store.used_bytes());

    // A second shrink of a shrunken store stays exact.
    let freed2 = store.shrink_to(256 << 10);
    assert_eq!(store.max_bytes(), 256 << 10);
    assert!(store.used_bytes() <= 256 << 10);
    assert!(freed2 > 0);

    // Growing back restores the exact total budget.
    store.grow_to(4 << 20);
    assert_eq!(store.max_bytes(), 4 << 20);
}

#[test]
fn concurrent_shrink_while_serving() {
    // Budget reclaim racing live traffic must keep invariants; the final
    // budget must be what the last shrink set.
    let store = Arc::new(ShardedKvStore::new(16 << 20, 8, 13));
    for i in 0..8000u32 {
        store.put(format!("k{i}").as_bytes(), &vec![2u8; 1024]);
    }
    let traffic: Vec<_> = (0..4)
        .map(|t| {
            let store = store.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(500 + t);
                let mut buf = Vec::new();
                for _ in 0..10_000 {
                    let k = format!("k{}", rng.below(8000));
                    if rng.below(2) == 0 {
                        let _ = store.get_into(k.as_bytes(), &mut buf);
                    } else {
                        store.put(k.as_bytes(), &vec![3u8; 1024]);
                    }
                }
            })
        })
        .collect();
    let shrinker = {
        let store = store.clone();
        std::thread::spawn(move || {
            for step in 0..20u32 {
                let target: usize = (16 << 20) >> (step % 3); // 16M, 8M, 4M
                store.shrink_to(target);
                store.grow_to(16 << 20);
            }
            store.shrink_to(2 << 20);
        })
    };
    for h in traffic {
        h.join().unwrap();
    }
    shrinker.join().unwrap();
    assert_eq!(store.max_bytes(), 2 << 20);
    // Traffic stopped before the final shrink finished joining, so the
    // store must now fit its final budget.
    assert!(store.used_bytes() <= 2 << 20);
    assert!(store.live_bytes() <= store.used_bytes());
}

#[test]
fn sharded_tcp_server_concurrent_clients() {
    let server =
        ProducerStoreServer::start_sharded("127.0.0.1:0", 16 << 20, None, 9, 4).unwrap();
    let addr = server.addr();
    let handles: Vec<_> = (0..8)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = KvClient::connect(addr).unwrap();
                for i in 0..100 {
                    let key = format!("t{t}-k{i}");
                    assert!(c.put(key.as_bytes(), &vec![t as u8; 512]).unwrap());
                    assert_eq!(c.get(key.as_bytes()).unwrap(), Some(vec![t as u8; 512]));
                }
                for i in 0..100 {
                    assert!(c.delete(format!("t{t}-k{i}").as_bytes()).unwrap());
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let stats = server.stats();
    assert_eq!(stats.puts, 800);
    assert_eq!(stats.hits, 800);
    assert_eq!(stats.deletes, 800);
    assert_eq!(server.store().len(), 0);
    assert_eq!(server.store().used_bytes(), 0);
    server.stop();
}

//! Cross-module integration tests: the full producer/broker/consumer
//! composition, the TCP request path, lease lifecycle under reclaim, and
//! the experiment harnesses end to end.

use memtrade::broker::placement::ConsumerRequest;
use memtrade::broker::predictor::AvailabilityPredictor;
use memtrade::broker::pricing::{PricingEngine, PricingStrategy};
use memtrade::broker::Broker;
use memtrade::consumer::client::SecureKv;
use memtrade::core::config::{BrokerConfig, HarvesterConfig};
use memtrade::core::{ConsumerId, Money, ProducerId, SimTime, GIB};
use memtrade::mem::SwapDevice;
use memtrade::net::tcp::{KvClient, ProducerStoreServer};
use memtrade::net::wire::{Request, Response};
use memtrade::producer::Producer;
use memtrade::sim::cluster::{ClusterSim, ClusterSimConfig, ConsumerMode};
use memtrade::workload::apps::{AppKind, AppModel, AppRunner};

fn make_producer(kind: AppKind, seed: u64) -> Producer {
    let app = AppRunner::new(
        AppModel::preset(kind),
        16 << 20,
        SwapDevice::Ssd,
        Some(SimTime::from_mins(5)),
        seed,
    );
    Producer::new(ProducerId(seed), app, HarvesterConfig::default(), 64 << 20)
}

#[test]
fn full_stack_lease_and_serve() {
    // Producer harvests; broker grants; consumer stores and reads back
    // with real crypto through the manager, while the producer keeps
    // running its own workload.
    let mut producer = make_producer(AppKind::Redis, 1);
    let epoch = SimTime::from_secs(5);
    let mut now = SimTime::ZERO;
    for e in 1..=240u64 {
        now = SimTime::from_micros(e * epoch.as_micros());
        producer.tick(now, epoch);
    }
    assert!(producer.manager.harvestable_bytes() > GIB);

    let mut broker = Broker::new(
        BrokerConfig::default(),
        AvailabilityPredictor::fallback(288, 12),
        PricingEngine::new(PricingStrategy::FixedFraction, Money::from_dollars(1e-5), 2e-5),
    );
    broker.registry.register_producer(producer.id, 8.0);
    let rss_gb = producer.app.memory.shape().rss as f32 / GIB as f32;
    for t in 0..288u64 {
        broker.registry.report_usage(producer.id, SimTime::from_secs(t * 300), rss_gb);
    }
    broker
        .registry
        .update_producer_resources(producer.id, producer.manager.free_slabs(), 0.9, 0.9);
    broker.predictor.refresh(&mut broker.registry, now);
    broker.registry.register_consumer(ConsumerId(10));

    let leases = broker.request_memory(
        now,
        ConsumerRequest {
            consumer: ConsumerId(10),
            slabs: 8,
            min_slabs: 1,
            lease: SimTime::from_hours(1),
            max_price_per_slab_hour: None,
            latency_us_to: Default::default(),
            weights: None,
        },
    );
    assert!(!leases.is_empty());
    assert!(producer.manager.grant_lease(leases[0].clone(), 1_000_000_000));

    let mut secure = SecureKv::with_iv_seed(Some([1u8; 16]), true, 1, 5);
    for i in 0..500u32 {
        let mut t = |_p: u32, req: Request| -> Response {
            producer.manager.handle(ConsumerId(10), &req, now)
        };
        assert!(secure.put(&mut t, format!("key{i}").as_bytes(), &vec![i as u8; 512]));
    }
    // Producer keeps working; its own app is unaffected.
    let before = producer.app.baseline_latency_us();
    for e in 241..=300u64 {
        now = SimTime::from_micros(e * epoch.as_micros());
        let lat = producer.tick(now, epoch);
        assert!(lat < before * 2.0, "producer latency exploded: {lat}");
    }
    // Reads verify.
    let mut hits = 0;
    for i in 0..500u32 {
        let mut t = |_p: u32, req: Request| -> Response {
            producer.manager.handle(ConsumerId(10), &req, now)
        };
        if let Some(v) = secure.get(&mut t, format!("key{i}").as_bytes()) {
            assert_eq!(v, vec![i as u8; 512]);
            hits += 1;
        }
    }
    assert!(hits > 450, "only {hits}/500 survived");
}

#[test]
fn reclaim_under_pressure_evicts_consumer_data_not_producer_perf() {
    let mut producer = make_producer(AppKind::Redis, 2);
    let epoch = SimTime::from_secs(5);
    let mut now = SimTime::ZERO;
    for e in 1..=240u64 {
        now = SimTime::from_micros(e * epoch.as_micros());
        producer.tick(now, epoch);
    }
    let lease = memtrade::core::Lease {
        id: memtrade::core::LeaseId(1),
        consumer: ConsumerId(10),
        producer: producer.id,
        slabs: 16,
        slab_bytes: 64 << 20,
        start: now,
        duration: SimTime::from_hours(1),
        price_per_slab_hour: Money::from_dollars(1e-5),
    };
    assert!(producer.manager.grant_lease(lease, 1_000_000_000));
    let mut secure = SecureKv::with_iv_seed(Some([2u8; 16]), true, 1, 6);
    for i in 0..2000u32 {
        let mut t = |_p: u32, req: Request| -> Response {
            producer.manager.handle(ConsumerId(10), &req, now)
        };
        secure.put(&mut t, format!("k{i}").as_bytes(), &vec![0u8; 4096]);
    }
    let used_before = producer.manager.leased_bytes();

    // Burst: the guest needs its memory back — shrink the pool far below
    // the ~8 MB of stored consumer data so LRU eviction must fire.
    producer.manager.set_harvestable(2 << 20, now);
    assert!(producer.manager.leased_bytes() <= 2 << 20);
    assert!(producer.manager.leased_bytes() < used_before);
    // Reputation reflects the broken lease.
    assert!(producer.manager.reputation() < 1.0);

    // Consumer sees misses, not corruption.
    let mut miss = 0;
    for i in 0..2000u32 {
        let mut t = |_p: u32, req: Request| -> Response {
            producer.manager.handle(ConsumerId(10), &req, now)
        };
        match secure.get(&mut t, format!("k{i}").as_bytes()) {
            Some(v) => assert_eq!(v, vec![0u8; 4096]),
            None => miss += 1,
        }
    }
    assert!(miss > 0);
    assert_eq!(secure.stats.integrity_failures, 0);
}

#[test]
fn tcp_secure_path_with_rate_limit() {
    let server = ProducerStoreServer::start("127.0.0.1:0", 64 << 20, None, 5).unwrap();
    let mut client = KvClient::connect(server.addr()).unwrap();
    let mut secure = SecureKv::with_iv_seed(Some([3u8; 16]), true, 1, 7);
    let mut t = |_p: u32, req: Request| -> Response {
        client.call(&req).unwrap_or(Response::Error("io".into()))
    };
    for i in 0..200u32 {
        assert!(secure.put(&mut t, format!("k{i}").as_bytes(), &vec![7u8; 1024]));
    }
    for i in 0..200u32 {
        assert_eq!(
            secure.get(&mut t, format!("k{i}").as_bytes()),
            Some(vec![7u8; 1024])
        );
    }
    assert_eq!(secure.stats.integrity_failures, 0);
    server.stop();
}

#[test]
fn cluster_sim_composes_all_layers() {
    let mut sim = ClusterSim::new(ClusterSimConfig {
        n_producers: 4,
        n_consumers: 3,
        remote_fraction: 0.3,
        mode: ConsumerMode::Secure,
        n_keys: 3_000,
        value_size: 512,
        ops_per_epoch: 60,
        page_bytes: 32 << 20,
        seed: 3,
        harvest: true,
        use_pjrt: false,
    });
    sim.bootstrap();
    sim.run(SimTime::from_mins(3));
    assert!(sim.consumer_mean_latency() > 0.0);
    assert!(sim.leased_bytes() > 0);
    // All consumers got leases and did work.
    for c in &sim.consumers {
        assert!(c.lat.count() > 0);
    }
}

#[test]
fn figures_quick_all_run() {
    // Every experiment harness must at least produce its tables.
    for id in memtrade::figures::ALL {
        // Heavy ones are exercised by their own tests/examples; keep the
        // integration sweep to the fast set.
        if matches!(*id, "fig11" | "table2" | "fig10" | "predictor" | "fig8") {
            continue;
        }
        let tables = memtrade::figures::run(id, true)
            .unwrap_or_else(|e| panic!("figure {id} failed: {e}"));
        assert!(!tables.is_empty(), "figure {id} produced no tables");
    }
}

//! Differential tests: the AOT HLO artifacts executed via PJRT must agree
//! with the pure-Rust mirror (`runtime::arima_fallback`) — which in turn
//! mirrors python/compile/kernels/ref.py, the oracle the Pallas kernels
//! are pinned to by pytest. Skips (with a notice) when `make artifacts`
//! has not run.

use memtrade::runtime::arima_fallback as fb;
use memtrade::runtime::engine::{
    Engine, DEMAND_SIZES, FORECAST_HORIZON, FORECAST_WINDOW,
};
use memtrade::util::rng::Rng;

fn engine() -> Option<Engine> {
    let dir = Engine::default_dir();
    if !Engine::artifacts_present(&dir) {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(&dir).expect("artifacts present but failed to load"))
}

fn gen_series(rng: &mut Rng, n: usize, w: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| {
            let base = rng.uniform(4.0, 24.0);
            let amp = rng.uniform(0.0, 6.0);
            let noise = rng.uniform(0.05, 0.8);
            let mut ar = 0.0f64;
            (0..w)
                .map(|t| {
                    ar = 0.85 * ar + rng.normal(0.0, noise);
                    let season =
                        amp * (std::f64::consts::TAU * t as f64 / 288.0).sin();
                    (base + season + ar).max(0.0) as f32
                })
                .collect()
        })
        .collect()
}

#[test]
fn forecast_artifact_matches_rust_mirror() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(11);
    // Deliberately not a multiple of the compiled batch: exercises padding.
    let n = 300;
    let series = gen_series(&mut rng, n, FORECAST_WINDOW);
    let caps: Vec<f32> = (0..n).map(|_| rng.uniform(16.0, 64.0) as f32).collect();

    let got = eng.forecast.predict(&series, &caps).expect("predict");
    let want = fb::forecast_batch(&series, &caps, 4, FORECAST_HORIZON, FORECAST_WINDOW);

    assert_eq!(got.len(), n);
    let mut selection_agree = 0;
    for i in 0..n {
        // f32 kernel vs f64 mirror: tolerances account for the precision
        // gap; the *decisions* (selection, safe margin) must agree closely.
        for h in 0..FORECAST_HORIZON {
            let g = got[i].pred[h];
            let w = want[i].pred[h];
            assert!(
                (g - w).abs() < 0.05 * w.abs().max(1.0),
                "series {i} h {h}: pjrt {g} rust {w}"
            );
            let gs = got[i].safe[h];
            let ws = want[i].safe[h];
            assert!(
                (gs - ws).abs() < 0.08 * caps[i].max(1.0),
                "series {i} safe h {h}: pjrt {gs} rust {ws}"
            );
        }
        assert!(
            (got[i].sigma - want[i].sigma).abs() < 0.05 * want[i].sigma.max(0.1),
            "series {i} sigma: {} vs {}",
            got[i].sigma,
            want[i].sigma
        );
        if got[i].used_diff == want[i].used_diff {
            selection_agree += 1;
        }
    }
    // Model selection may flip on near-ties under f32; demand >95% agreement.
    assert!(selection_agree * 100 >= n * 95, "selection agreement {selection_agree}/{n}");
}

#[test]
fn forecast_artifact_sane_on_patterns() {
    let Some(eng) = engine() else { return };
    // Constant series: forecast == constant, safe == cap - constant (+~0).
    let series = vec![vec![10.0f32; FORECAST_WINDOW]; 3];
    let caps = vec![32.0f32; 3];
    let got = eng.forecast.predict(&series, &caps).unwrap();
    for r in &got {
        for h in 0..FORECAST_HORIZON {
            assert!((r.pred[h] - 10.0).abs() < 0.1, "pred {}", r.pred[h]);
            assert!((r.safe[h] - 22.0).abs() < 0.5, "safe {}", r.safe[h]);
        }
    }
    // Ramp: d=1 wins and extrapolates upward.
    let ramp: Vec<f32> = (0..FORECAST_WINDOW).map(|t| 0.05 * t as f32).collect();
    let got = eng.forecast.predict(&[ramp.clone()], &[64.0]).unwrap();
    assert!(got[0].used_diff);
    assert!(got[0].pred[FORECAST_HORIZON - 1] > *ramp.last().unwrap());
}

#[test]
fn demand_artifact_matches_rust_mirror() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(13);
    let n = 1500; // exercises chunking (compiled batch 1024)
    let gains: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let rate = rng.uniform(10.0, 3000.0);
            let knee = rng.uniform(2.0, 48.0);
            (0..DEMAND_SIZES)
                .map(|s| (rate * (1.0 - (-(s as f64) / knee).exp())) as f32)
                .collect()
        })
        .collect();
    let values: Vec<f32> = (0..n).map(|_| rng.uniform(1e-6, 1e-3) as f32).collect();
    let prices = [0.0008f32, 0.0010, 0.0012];

    let got = eng.demand.evaluate(&gains, &values, prices).unwrap();
    assert_eq!(got.demand.len(), n);
    let mut total = [0f64; 3];
    for i in 0..n {
        for k in 0..3 {
            let want = fb::demand_one(&gains[i], values[i], prices[k] as f64);
            let g = got.demand[i][k];
            // Ties at the argmax can differ by one slab between f32/f64.
            assert!(
                (g - want as f32).abs() <= 1.0,
                "consumer {i} price {k}: pjrt {g} rust {want}"
            );
            total[k] += g as f64;
        }
    }
    for k in 0..3 {
        assert!((got.volume[k] - total[k]).abs() < 1e-6);
        assert!((got.revenue[k] - got.volume[k] * prices[k] as f64).abs() < 1e-9);
    }
}

#[test]
fn manifest_matches_compiled_constants() {
    let dir = Engine::default_dir();
    if !Engine::artifacts_present(&dir) {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    memtrade::runtime::engine::check_manifest(&dir).expect("manifest check");
}

//! End-to-end request tracing over real TCP: one consumer `SecureKv`
//! call yields a causal span chain crossing all three roles — consumer
//! root → pool route → wire → producer shard — fetchable live through
//! the `TraceQuery` control verb, with the broker's grant span adopted
//! from the lease request's trace and `data.op_us` p99 exemplars that
//! resolve to recorded trace ids. Also pins the hot-path contract:
//! recording a span allocates nothing once a thread's ring is warm.

use memtrade::consumer::client::SecureKv;
use memtrade::core::config::BrokerConfig;
use memtrade::core::SimTime;
use memtrade::market::{
    BrokerServer, BrokerServerConfig, ProducerAgent, ProducerAgentConfig,
    RemotePool, RemotePoolConfig,
};
use memtrade::metrics::MetricSet;
use memtrade::net::control::{CtrlClient, CtrlRequest, CtrlResponse};
use memtrade::trace::{Op, Role, Span, SpanGuard, Status};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::collections::{HashMap, HashSet};
use std::time::{Duration, Instant};

const SLAB: u64 = 1 << 20;

// ---------------------------------------------------------------- alloc probe

/// Counts allocations per thread so the hot-path test can prove span
/// recording is allocation-free (the system allocator still serves).
struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

// SAFETY: pure delegation to the System allocator; the only extra work
// is a thread-local counter bump, which cannot allocate or unwind.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: same layout contract as `System.alloc`, forwarded as-is.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }
    // SAFETY: `ptr`/`layout` come from the paired `alloc` above, which
    // got them from `System`; forwarding preserves the contract.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

#[test]
fn span_recording_allocates_nothing_after_ring_warm_up() {
    // A thread's first span allocates its ring and registers it in the
    // process registry; every span after that is one atomic index bump
    // plus eight relaxed word stores.
    for _ in 0..4 {
        let mut warm = SpanGuard::root(Role::Consumer, Op::Get);
        warm.set_status(Status::Ok);
    }
    let before = ALLOCS.with(|c| c.get());
    for i in 0..1_000u64 {
        let mut span = SpanGuard::root(Role::Consumer, Op::Get);
        span.set_lease(i);
        span.set_producer(i % 7);
        span.set_status(if i % 3 == 0 { Status::Miss } else { Status::Ok });
    }
    let allocs = ALLOCS.with(|c| c.get()) - before;
    assert_eq!(allocs, 0, "hot-path span recording allocated {allocs} time(s)");
}

// --------------------------------------------------------------- e2e tracing

fn broker_cfg() -> BrokerConfig {
    BrokerConfig {
        slab_bytes: SLAB,
        min_lease: SimTime::from_millis(800),
        ..Default::default()
    }
}

fn server_cfg() -> BrokerServerConfig {
    BrokerServerConfig {
        tick: Duration::from_millis(20),
        producer_timeout: Duration::from_secs(30),
        forecast_min_samples: usize::MAX,
        ..Default::default()
    }
}

fn start_agent(broker: &BrokerServer, id: u64, capacity: u64) -> ProducerAgent {
    ProducerAgent::start(ProducerAgentConfig {
        producer: id,
        brokers: vec![broker.addr().to_string()],
        data_addr: "127.0.0.1:0".to_string(),
        capacity_bytes: capacity,
        heartbeat: Duration::from_millis(50),
        shards: 2,
        seed: id,
        ..Default::default()
    })
    .expect("agent start")
}

fn fetch_spans(addr: std::net::SocketAddr) -> Vec<Span> {
    let mut ctrl = CtrlClient::connect(addr).expect("trace dial");
    match ctrl.call(&CtrlRequest::TraceQuery { max: 4096 }).expect("trace call") {
        CtrlResponse::Traces { spans } => spans,
        other => panic!("unexpected trace reply: {other:?}"),
    }
}

fn query_stats(addr: std::net::SocketAddr) -> MetricSet {
    let mut ctrl = CtrlClient::connect(addr).expect("stats dial");
    match ctrl.call(&CtrlRequest::StatsQuery).expect("stats call") {
        CtrlResponse::Stats { metrics, .. } => metrics,
        other => panic!("unexpected stats reply: {other:?}"),
    }
}

/// Finds a complete cross-role chain: producer shard span whose parent
/// walk is wire → route → a `MultiGet` consumer root, all four sharing
/// one trace id. Returns `[root, route, wire, shard]`.
fn find_chain(spans: &[Span]) -> Option<[Span; 4]> {
    let by_id: HashMap<u64, &Span> = spans.iter().map(|s| (s.span_id, s)).collect();
    for shard in spans.iter().filter(|s| s.role == Role::Producer && s.op == Op::Shard) {
        let Some(wire) = by_id.get(&shard.parent) else { continue };
        let Some(route) = by_id.get(&wire.parent) else { continue };
        let Some(root) = by_id.get(&route.parent) else { continue };
        let same_trace = [wire, route, root].iter().all(|s| s.trace_id == shard.trace_id);
        if same_trace
            && wire.role == Role::Consumer
            && wire.op == Op::Wire
            && route.op == Op::Route
            && root.parent == 0
            && root.role == Role::Consumer
            && root.op == Op::MultiGet
        {
            return Some([**root, **route, **wire, *shard]);
        }
    }
    None
}

#[test]
fn trace_query_returns_cross_role_span_chain_with_p99_exemplars() {
    let broker = BrokerServer::start("127.0.0.1:0", broker_cfg(), server_cfg()).unwrap();
    let agents = vec![start_agent(&broker, 1, 16 * SLAB), start_agent(&broker, 2, 16 * SLAB)];
    let mut pool = RemotePool::connect(RemotePoolConfig {
        consumer: 9,
        brokers: vec![broker.addr().to_string()],
        target_slabs: 8,
        min_slabs: 1,
        lease_ttl: Duration::from_secs(10),
        renew_margin: Duration::from_secs(2),
        maintain_every: Duration::from_millis(20),
        ..Default::default()
    })
    .unwrap();

    // Lease real capacity first so ops actually travel the wire.
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline && pool.held_slabs() == 0 {
        pool.maintain();
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(pool.held_slabs() > 0, "pool never acquired slabs");

    let mut secure = SecureKv::with_iv_seed(Some([7u8; 16]), true, 1, 3);
    let value = vec![0xCD_u8; 512];
    for i in 0..32u32 {
        let key = format!("tkey{i}");
        let _ = secure.put(&mut pool, key.as_bytes(), &value);
    }
    let keys: Vec<String> = (0..8).map(|i| format!("tkey{i}")).collect();
    let key_refs: Vec<&[u8]> = keys.iter().map(|k| k.as_bytes()).collect();
    let _ = secure.multi_get(&mut pool, &key_refs);

    // Server-side spans record on conn threads asynchronously; poll the
    // live rings over the new `TraceQuery` verb until the chain lands.
    let deadline = Instant::now() + Duration::from_secs(10);
    let (mut spans, mut chain) = (Vec::new(), None);
    while Instant::now() < deadline && chain.is_none() {
        spans = fetch_spans(broker.addr());
        chain = find_chain(&spans);
        if chain.is_none() {
            let key_refs: Vec<&[u8]> = keys.iter().map(|k| k.as_bytes()).collect();
            let _ = secure.multi_get(&mut pool, &key_refs);
            std::thread::sleep(Duration::from_millis(25));
        }
    }
    let [_root, route, wire, shard] =
        chain.expect("no consumer→route→wire→shard chain in TraceQuery spans");
    assert_ne!(route.lease_id, 0, "route span should carry the lease it picked");
    assert!(
        shard.producer_id == 1 || shard.producer_id == 2,
        "shard span names the wrong producer: {shard:?}"
    );
    assert!(shard.t_start_us >= wire.t_start_us, "shard started before its wire parent");

    // The broker joined the lease-request trace: a Broker-role grant
    // span adopted from the pool's `RequestSlabs { trace, .. }`.
    assert!(
        spans.iter().any(|s| s.role == Role::Broker && s.op == Op::Grant && s.trace_id != 0),
        "no broker-side Grant span adopted from the RequestSlabs trace"
    );

    // `data.op_us` top-bucket exemplars pin trace ids: the slowest
    // observed op resolves to a trace the rings still hold.
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut exemplar_hit = false;
    while Instant::now() < deadline && !exemplar_hit {
        let ids: HashSet<u64> = fetch_spans(broker.addr()).iter().map(|s| s.trace_id).collect();
        for a in &agents {
            let Some(stats) = a.stats_addr() else { continue };
            let m = query_stats(stats);
            let Some(h) = m.histogram("data.op_us") else { continue };
            if let Some(ex) = h.p99_exemplar() {
                if ex != 0 && ids.contains(&ex) {
                    exemplar_hit = true;
                    break;
                }
            }
        }
        if !exemplar_hit {
            std::thread::sleep(Duration::from_millis(25));
        }
    }
    assert!(exemplar_hit, "no p99 exemplar resolved to a recorded trace id");

    drop(pool);
    for a in agents {
        a.stop();
    }
    broker.stop();
}

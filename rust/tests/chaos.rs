//! Chaos soak suite: ≥ 20 seeded fault schedules across both
//! marketplace planes, asserting the paper's resilience invariants —
//! no panic, zero integrity escapes, no lost acknowledged writes on
//! surviving producers, and reconvergence to target capacity once
//! faults stop.
//!
//! Every schedule prints its seed and a one-line reproduction command
//! before it runs, so a red CI job is replayable locally:
//! `cargo run --release -- chaos --seed <seed> --mix <mix>`.

use memtrade::consumer::client::{KvTransport, SecureKv};
use memtrade::market::chaos::{run_chaos, ChaosConfig, ChaosMix, ChaosOutcome};
use memtrade::net::faults::{ByzantineSpec, FaultPlan, FaultSpec};
use memtrade::net::tcp::{KvClient, ProducerStoreServer};
use memtrade::net::wire::{Request, Response};
use memtrade::util::rng::Rng;
use std::collections::HashMap;
use std::time::Duration;

/// A `KvClient` as a transport that *remembers* I/O death, so faulty-
/// pair schedules can reconnect — and that sends `SecureKv` multi-ops
/// as true batch frames (the point of the batch fault schedules).
struct ClientTransport<'a> {
    client: &'a mut KvClient,
    dead: bool,
}

impl KvTransport for ClientTransport<'_> {
    fn call(&mut self, _p: u32, req: Request) -> Response {
        self.client.call(&req).unwrap_or_else(|_| {
            self.dead = true;
            Response::Error("io".into())
        })
    }

    fn call_multi(&mut self, _p: u32, reqs: Vec<Request>) -> Vec<Response> {
        let n = reqs.len();
        self.client.call_batch(&reqs).unwrap_or_else(|_| {
            self.dead = true;
            vec![Response::Error("io".into()); n]
        })
    }
}

fn assert_invariants(o: &ChaosOutcome) {
    println!("chaos outcome: {}", o.report());
    let violations = o.invariant_violations();
    assert!(
        violations.is_empty(),
        "invariants violated for seed {} — reproduce with `memtrade chaos --seed {} --mix \
         <mix>`:\n  schedule: {}\n  {}",
        o.seed,
        o.seed,
        o.schedule,
        violations.join("\n  ")
    );
}

/// CI sets `MEMTRADE_DUMP_DIR` so every schedule's flight-recorder
/// dumps land in one workspace dir, uploaded as artifacts on failure.
fn env_dump_dir() -> Option<std::path::PathBuf> {
    std::env::var_os("MEMTRADE_DUMP_DIR").map(std::path::PathBuf::from)
}

fn run_marketplace_schedule(seed: u64, mix: ChaosMix) -> ChaosOutcome {
    println!(
        "chaos schedule: marketplace seed={seed} mix={} (reproduce: memtrade chaos --seed \
         {seed} --mix {})",
        mix.label(),
        mix.label()
    );
    run_chaos(&ChaosConfig { seed, mix, dump_dir: env_dump_dir(), ..Default::default() })
}

// --- Full-topology schedules (broker + 2 agents + pool over TCP). ---

#[test]
fn chaos_marketplace_control_plane_faults() {
    for seed in [101, 102] {
        let o = run_marketplace_schedule(seed, ChaosMix::from_name("control").unwrap());
        assert_invariants(&o);
    }
}

#[test]
fn chaos_marketplace_data_plane_faults() {
    for seed in [201, 202] {
        let o = run_marketplace_schedule(seed, ChaosMix::from_name("data").unwrap());
        assert_invariants(&o);
        assert!(o.ops > 0, "no traffic survived the data faults (seed {seed})");
    }
}

#[test]
fn chaos_marketplace_byzantine_producer() {
    let o = run_marketplace_schedule(301, ChaosMix::from_name("byzantine").unwrap());
    assert_invariants(&o);
    assert!(o.tampered > 0, "byzantine mode never fired — schedule too short");
    assert!(
        o.integrity_failures > 0,
        "tampered responses ({}) never reached the envelope",
        o.tampered
    );
}

/// One span as the flight recorder's fixed-order JSONL dumps it; only
/// the fields the chain check needs.
struct DumpSpan {
    trace_id: u64,
    span_id: u64,
    parent: u64,
    role: String,
    op: String,
}

fn json_u64(line: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let at = line.find(&pat)? + pat.len();
    let rest = &line[at..];
    let end = rest.find(&[',', '}'][..])?;
    rest[..end].parse().ok()
}

fn json_str(line: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":\"");
    let at = line.find(&pat)? + pat.len();
    line[at..].split('"').next().map(str::to_string)
}

fn parse_dump_span(line: &str) -> Option<DumpSpan> {
    Some(DumpSpan {
        trace_id: json_u64(line, "trace_id")?,
        span_id: json_u64(line, "span_id")?,
        parent: json_u64(line, "parent")?,
        role: json_str(line, "role")?,
        op: json_str(line, "op")?,
    })
}

/// True when the spans hold a cross-role causal chain from one data op:
/// producer shard → consumer wire → consumer route, one trace id, with
/// the route pointing at a (possibly still-open) consumer root. The
/// integrity dump fires *inside* the consumer op, so its root span has
/// not reached the ring yet — the three closed spans have.
fn has_cross_role_chain(spans: &[DumpSpan]) -> bool {
    let by_id: HashMap<u64, &DumpSpan> = spans.iter().map(|s| (s.span_id, s)).collect();
    spans.iter().any(|shard| {
        shard.role == "producer"
            && shard.op == "shard"
            && by_id.get(&shard.parent).is_some_and(|wire| {
                wire.trace_id == shard.trace_id
                    && wire.role == "consumer"
                    && wire.op == "wire"
                    && by_id.get(&wire.parent).is_some_and(|route| {
                        route.trace_id == shard.trace_id
                            && route.role == "consumer"
                            && route.op == "route"
                            && route.parent != 0
                    })
            })
    })
}

#[test]
fn chaos_byzantine_tamper_dumps_flight_recorder_span_chain() {
    // A tampered response must not only die at the envelope — it must
    // leave evidence: the consumer dumps its recent spans as JSONL, and
    // the dump holds the causal chain of the poisoned op across roles.
    let (dir, ephemeral) = match env_dump_dir() {
        Some(d) => (d, false),
        None => {
            let d = std::env::temp_dir()
                .join(format!("memtrade-chaos-dumps-{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&d);
            (d, true)
        }
    };
    println!(
        "chaos schedule: marketplace seed=901 mix=byzantine (reproduce: memtrade chaos \
         --seed 901 --mix byzantine --dump-dir {})",
        dir.display()
    );
    let o = run_chaos(&ChaosConfig {
        seed: 901,
        mix: ChaosMix::from_name("byzantine").unwrap(),
        dump_dir: Some(dir.clone()),
        ..Default::default()
    });
    assert_invariants(&o);
    assert!(o.tampered > 0, "byzantine mode never fired — schedule too short");
    assert!(o.integrity_failures > 0, "tampering never reached the envelope");
    assert!(!o.dump_files.is_empty(), "integrity failures produced no flight-recorder dumps");

    let integrity_dumps: Vec<_> = o
        .dump_files
        .iter()
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("consumer-integrity-"))
        })
        .collect();
    assert!(!integrity_dumps.is_empty(), "no consumer-integrity dump: {:?}", o.dump_files);
    let chain_found = integrity_dumps.iter().any(|p| {
        let text = std::fs::read_to_string(p).unwrap_or_default();
        let spans: Vec<DumpSpan> = text.lines().filter_map(parse_dump_span).collect();
        has_cross_role_chain(&spans)
    });
    assert!(
        chain_found,
        "no consumer→route→wire→shard chain with matching trace ids in any integrity dump"
    );
    if ephemeral {
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn chaos_marketplace_mid_run_kill() {
    for seed in [401, 402] {
        let o = run_marketplace_schedule(seed, ChaosMix::from_name("data+kill").unwrap());
        assert_invariants(&o);
    }
}

#[test]
fn chaos_marketplace_renew_vs_revoke_race() {
    for seed in [501, 502] {
        let o = run_marketplace_schedule(seed, ChaosMix::from_name("control+race").unwrap());
        assert_invariants(&o);
    }
}

#[test]
fn chaos_marketplace_failover_takeover() {
    // Kill the primary broker mid-run with a warm standby replicating
    // its lease-event log. Beyond the shared invariants (no lost acked
    // writes, zero escapes, reconvergence), the standby must have taken
    // over exactly once — `Some(0)` means clients reconverged against
    // nothing, which the invariant check already rejects.
    for seed in [701, 702] {
        let o = run_marketplace_schedule(seed, ChaosMix::failover());
        assert_invariants(&o);
        assert_eq!(o.broker_takeovers, Some(1), "seed {seed}: takeovers {:?}", o.broker_takeovers);
        assert!(o.ops > 0, "no traffic survived the failover (seed {seed})");
    }
}

#[test]
fn chaos_marketplace_failover_under_data_faults() {
    // Failover while the data plane is also faulty: the promoted
    // standby's re-registered producers keep serving through the same
    // fault schedules, and the integrity envelope still catches every
    // corruption.
    let o = run_marketplace_schedule(801, ChaosMix::from_name("data+failover").unwrap());
    assert_invariants(&o);
    assert_eq!(o.broker_takeovers, Some(1), "takeovers {:?}", o.broker_takeovers);
}

#[test]
fn chaos_marketplace_standard_mix() {
    // Everything at once: control + data faults, Byzantine producer,
    // mid-run kill, revocation race. Every producer store in the
    // schedule is the epoll readiness-loop server (`start_chaotic`
    // defaults to it), so this run is the proof that the async rewrite
    // preserves the 100%-envelope-catch and no-lost-acked-writes
    // invariants under the standard fault mix.
    let o = run_marketplace_schedule(601, ChaosMix::standard());
    assert_invariants(&o);
}

// --- Light data-plane schedules: one faulty client/server pair. -----

/// Derive a data-plane fault spec from a seed (wider rates than the
/// marketplace runner — here nothing needs to *recover*, only to never
/// panic and never escape the envelope).
fn light_spec(rng: &mut Rng) -> FaultSpec {
    FaultSpec {
        drop_p: rng.uniform(0.0, 0.08),
        delay_p: rng.uniform(0.0, 0.05),
        delay_max_ms: 1 + rng.below(5),
        disconnect_p: rng.uniform(0.0, 0.03),
        truncate_p: rng.uniform(0.0, 0.05),
        duplicate_p: rng.uniform(0.0, 0.06),
        bitflip_p: rng.uniform(0.0, 0.06),
    }
}

/// One seeded schedule against a single chaotic producer store: drive
/// secure traffic through reconnecting faulty clients; assert zero
/// escapes and that the pair is fully usable once the plan disarms.
fn run_light_schedule(seed: u64) {
    println!("chaos schedule: data-plane pair seed={seed}");
    let mut rng = Rng::new(seed ^ 0x11);
    let server_plan = FaultPlan::new(seed ^ 0x51, light_spec(&mut rng), light_spec(&mut rng));
    let client_plan = FaultPlan::new(seed ^ 0xC1, light_spec(&mut rng), light_spec(&mut rng));
    let server = ProducerStoreServer::start_chaotic(
        "127.0.0.1:0",
        8 << 20,
        None,
        seed,
        2,
        Some(server_plan.clone()),
        None,
    )
    .unwrap();
    let addr = server.addr().to_string();

    let mut secure = SecureKv::with_iv_seed(Some([0xAA; 16]), true, 1, seed);
    let mut client: Option<KvClient> = None;
    let mut conn_seq = 0u64;
    let value = |k: u64| -> Vec<u8> { vec![(seed ^ k) as u8; 64 + (k as usize % 64)] };
    let mut escapes = 0u64;
    for op in 0..250u64 {
        // Reconnect through the faulty dialer when the last connection
        // died; a refused dial is just a miss for this op.
        if client.is_none() {
            conn_seq += 1;
            client = KvClient::connect_faulty(
                &addr,
                Duration::from_millis(500),
                &client_plan,
                conn_seq,
            )
            .ok()
            .map(|mut c| {
                let _ = c.set_call_timeout(Some(Duration::from_millis(100)));
                c
            });
        }
        let mut dead = false;
        {
            let mut transport = |_p: u32, req: Request| -> Response {
                match client.as_mut() {
                    Some(c) => c.call(&req).unwrap_or_else(|_| {
                        dead = true;
                        Response::Error("io".into())
                    }),
                    None => Response::Error("not connected".into()),
                }
            };
            let k = op % 40;
            let key = format!("k{k}").into_bytes();
            if op % 3 == 0 {
                let _ = secure.put(&mut transport, &key, &value(k));
            } else if let Some(v) = secure.get(&mut transport, &key) {
                if v != value(k) {
                    escapes += 1;
                }
            }
        }
        if dead {
            client = None;
        }
    }
    assert_eq!(escapes, 0, "integrity escape under data faults (seed {seed})");

    // Disarm both sides: a fresh clean connection must round-trip,
    // proving the store itself survived the storm undamaged.
    server_plan.disarm();
    client_plan.disarm();
    let mut clean = KvClient::connect(server.addr()).unwrap();
    assert!(clean.put(b"post-chaos", b"alive").unwrap());
    assert_eq!(clean.get(b"post-chaos").unwrap(), Some(b"alive".to_vec()));
    server.stop();
}

#[test]
fn chaos_data_plane_faulty_pairs() {
    // Twelve independent seeded schedules (cheap: one server + one
    // reconnecting client each).
    for seed in [11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21, 22] {
        run_light_schedule(seed);
    }
}

/// One seeded schedule of *batch* traffic against a chaotic pair: every
/// op travels inside a MultiGet/MultiPut frame, so write-side truncation
/// cuts between batch ops and duplication doubles whole batch responses
/// — the frames either decode fully or the connection dies; a batch
/// must never produce a wrong verified value or a panic.
fn run_batch_schedule(seed: u64) {
    println!("chaos schedule: batched data-plane pair seed={seed}");
    let mut rng = Rng::new(seed ^ 0xBA7C);
    let server_plan = FaultPlan::new(seed ^ 0x5B, light_spec(&mut rng), light_spec(&mut rng));
    let client_plan = FaultPlan::new(seed ^ 0xCB, light_spec(&mut rng), light_spec(&mut rng));
    let server = ProducerStoreServer::start_chaotic(
        "127.0.0.1:0",
        8 << 20,
        None,
        seed,
        4,
        Some(server_plan.clone()),
        None,
    )
    .unwrap();
    let addr = server.addr().to_string();

    let mut secure = SecureKv::with_iv_seed(Some([0xBB; 16]), true, 1, seed);
    let mut client: Option<KvClient> = None;
    let mut conn_seq = 0u64;
    let value = |k: u64| -> Vec<u8> { vec![(seed ^ k) as u8; 48 + (k as usize % 48)] };
    let mut escapes = 0u64;
    for round in 0..60u64 {
        if client.is_none() {
            conn_seq += 1;
            client = KvClient::connect_faulty(
                &addr,
                Duration::from_millis(500),
                &client_plan,
                conn_seq,
            )
            .ok()
            .map(|mut c| {
                let _ = c.set_call_timeout(Some(Duration::from_millis(100)));
                c.set_window(2);
                c
            });
        }
        let Some(c) = client.as_mut() else { continue };
        let mut t = ClientTransport { client: c, dead: false };
        let ks: Vec<u64> = (0..6).map(|j| (round * 3 + j) % 30).collect();
        let keys: Vec<Vec<u8>> = ks.iter().map(|k| format!("bk{k}").into_bytes()).collect();
        if round % 3 == 0 {
            let vals: Vec<Vec<u8>> = ks.iter().map(|&k| value(k)).collect();
            let items: Vec<(&[u8], &[u8])> = keys
                .iter()
                .zip(&vals)
                .map(|(k, v)| (k.as_slice(), v.as_slice()))
                .collect();
            let _ = secure.multi_put(&mut t, &items);
        } else {
            let key_refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
            for (j, got) in secure.multi_get(&mut t, &key_refs).into_iter().enumerate() {
                if let Some(v) = got {
                    if v != value(ks[j]) {
                        escapes += 1;
                    }
                }
            }
        }
        if t.dead {
            client = None;
        }
    }
    assert_eq!(escapes, 0, "integrity escape in a batch (seed {seed})");

    // Disarm both sides: a clean connection's batches must round-trip,
    // proving the store survived the batched storm undamaged.
    server_plan.disarm();
    client_plan.disarm();
    let mut clean = KvClient::connect(server.addr()).unwrap();
    let pairs: [(&[u8], &[u8]); 2] = [(b"post-a", b"1"), (b"post-b", b"2")];
    assert_eq!(clean.multi_put(&pairs).unwrap(), vec![true, true]);
    let keys: [&[u8]; 2] = [b"post-a", b"post-b"];
    assert_eq!(
        clean.multi_get(&keys).unwrap(),
        vec![Some(b"1".to_vec()), Some(b"2".to_vec())]
    );
    server.stop();
}

#[test]
fn chaos_batch_frames_under_faulty_pairs() {
    for seed in [31, 32, 33, 34, 35, 36] {
        run_batch_schedule(seed);
    }
}

/// Batched GETs against a producer that tampers *every* hit: the
/// envelope must reject each batched op individually — 100% caught,
/// zero escapes, exactly as the single-op guarantee.
#[test]
fn chaos_byzantine_batches_caught_at_full_tamper_rate() {
    for seed in [91, 92] {
        println!("chaos schedule: byzantine batches tamper_p=1.0 seed={seed}");
        let server = ProducerStoreServer::start_chaotic(
            "127.0.0.1:0",
            8 << 20,
            None,
            seed,
            2,
            None,
            Some(ByzantineSpec::new(seed, 1.0)),
        )
        .unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        let mut secure = SecureKv::with_iv_seed(Some([0x99; 16]), true, 1, seed);
        const N: u64 = 96;
        {
            let mut t = ClientTransport { client: &mut client, dead: false };
            let keys: Vec<Vec<u8>> = (0..N).map(|i| format!("k{i}").into_bytes()).collect();
            let vals: Vec<Vec<u8>> = (0..N).map(|i| vec![i as u8; 80]).collect();
            let items: Vec<(&[u8], &[u8])> = keys
                .iter()
                .zip(&vals)
                .map(|(k, v)| (k.as_slice(), v.as_slice()))
                .collect();
            assert_eq!(secure.multi_put(&mut t, &items), vec![true; N as usize]);
            // One giant multi-get: every op inside the batch is served
            // tampered, and every single one must die at the envelope.
            let key_refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
            let got = secure.multi_get(&mut t, &key_refs);
            assert!(
                got.iter().all(Option::is_none),
                "a tampered batched op escaped the envelope (seed {seed})"
            );
        }
        assert_eq!(secure.stats.integrity_failures, N, "seed {seed}");
        assert_eq!(secure.stats.hits, 0, "seed {seed}");
        assert_eq!(server.byzantine_tampered(), N, "seed {seed}");
        server.stop();
    }
}

// --- Epoll data plane: half-open peers must not pin memory. ----------

/// Resident-set size of this process in bytes, from `/proc/self/statm`
/// (the epoll server under test is Linux-only, so the probe can be
/// too).
#[cfg(target_os = "linux")]
fn rss_bytes() -> u64 {
    let statm = std::fs::read_to_string("/proc/self/statm").unwrap();
    let resident_pages: u64 =
        statm.split_whitespace().nth(1).unwrap().parse().unwrap();
    resident_pages * 4096
}

/// 50 slow-loris peers against the epoll producer store: each one
/// either (a) connects and goes silent, (b) sends a torn hello length
/// prefix, or (c) completes the handshake and then sends a frame
/// header *declaring* an 8 MiB body followed by only 100 real bytes —
/// then holds the connection half-open. The reassembly state machine
/// buffers only received bytes, never the declared length, so the
/// server's steady-state memory must stay flat (an eager-allocation
/// regression would pin 50 × 8 MiB = 400 MiB here) and a live consumer
/// sharing the same event loops must keep round-tripping unimpeded.
#[cfg(target_os = "linux")]
#[test]
fn chaos_half_open_connections_pin_no_memory_and_never_stall_live_traffic() {
    use memtrade::net::control::{client_handshake, DATA_MAGIC};
    use std::io::Write;

    println!("chaos schedule: 50 half-open slow-loris peers vs epoll data plane");
    let server =
        ProducerStoreServer::start_sharded("127.0.0.1:0", 8 << 20, None, 1177, 2).unwrap();
    let mut live = KvClient::connect(server.addr()).unwrap();
    let _ = live.set_call_timeout(Some(Duration::from_secs(2)));
    assert!(live.put(b"canary", &[0x5A; 512]).unwrap());

    let rss_before = rss_bytes();
    let mut half_open = Vec::new();
    for i in 0..50u32 {
        let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
        match i % 3 {
            // Connected, never speaks: parked in the pre-hello state.
            0 => {}
            // A torn frame header: 3 of the 4 length-prefix bytes.
            1 => stream.write_all(&[0xFF, 0xFF, 0x00]).unwrap(),
            // Fully admitted, then a giant declared frame that never
            // arrives: 8 MiB announced, 100 bytes sent.
            _ => {
                client_handshake(&mut (&stream), &mut (&stream), DATA_MAGIC).unwrap();
                stream.write_all(&((8u32 << 20).to_le_bytes())).unwrap();
                stream.write_all(&[0xAB; 100]).unwrap();
            }
        }
        half_open.push(stream);
    }
    // Let the loops observe and park every half-open peer.
    std::thread::sleep(Duration::from_millis(200));

    // Live traffic shares the same event loops as the 50 parked
    // connections; every round trip must still complete (the 2 s call
    // timeout turns a stall into a hard failure).
    for op in 0..200u64 {
        let key = format!("live{}", op % 20);
        if op % 4 == 0 {
            assert!(live.put(key.as_bytes(), &[op as u8; 512]).unwrap());
        } else {
            let _ = live.get(key.as_bytes()).unwrap();
        }
    }
    assert_eq!(live.get(b"canary").unwrap(), Some(vec![0x5A; 512]));

    let growth = rss_bytes().saturating_sub(rss_before);
    assert!(
        growth < 64 << 20,
        "50 half-open connections grew RSS by {} MiB — declared-length \
         allocation is back (must buffer received bytes only)",
        growth >> 20
    );
    drop(half_open);
    server.stop();
}

// --- Byzantine producer: the envelope must catch 100%. --------------

#[test]
fn chaos_byzantine_producer_caught_at_full_tamper_rate() {
    for seed in [71, 72] {
        println!("chaos schedule: byzantine tamper_p=1.0 seed={seed}");
        let server = ProducerStoreServer::start_chaotic(
            "127.0.0.1:0",
            8 << 20,
            None,
            seed,
            2,
            None,
            Some(ByzantineSpec::new(seed, 1.0)),
        )
        .unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        let mut secure = SecureKv::with_iv_seed(Some([0x77; 16]), true, 1, seed);
        let mut transport = |_p: u32, req: Request| -> Response {
            client.call(&req).unwrap_or(Response::Error("io".into()))
        };
        const N: u64 = 120;
        for i in 0..N {
            let key = format!("k{i}").into_bytes();
            assert!(secure.put(&mut transport, &key, &[i as u8; 96]));
        }
        // Every single GET is tampered with; every single one must be
        // rejected by the envelope as a miss — zero escapes.
        for i in 0..N {
            let key = format!("k{i}").into_bytes();
            assert_eq!(
                secure.get(&mut transport, &key),
                None,
                "tampered response escaped the envelope (seed {seed}, key {i})"
            );
        }
        assert_eq!(secure.stats.integrity_failures, N, "seed {seed}");
        assert_eq!(secure.stats.hits, 0, "seed {seed}");
        assert_eq!(server.byzantine_tampered(), N, "seed {seed}");
        server.stop();
    }
}

// --- Partial tamper rate: hits that verify are the right bytes. ------

#[test]
fn chaos_byzantine_partial_rate_verified_hits_are_correct() {
    let seed = 81;
    println!("chaos schedule: byzantine tamper_p=0.4 seed={seed}");
    let server = ProducerStoreServer::start_chaotic(
        "127.0.0.1:0",
        8 << 20,
        None,
        seed,
        2,
        None,
        Some(ByzantineSpec::new(seed, 0.4)),
    )
    .unwrap();
    let mut client = KvClient::connect(server.addr()).unwrap();
    let mut secure = SecureKv::with_iv_seed(Some([0x88; 16]), true, 1, seed);
    let mut transport = |_p: u32, req: Request| -> Response {
        client.call(&req).unwrap_or(Response::Error("io".into()))
    };
    for i in 0..200u64 {
        let key = format!("k{i}").into_bytes();
        assert!(secure.put(&mut transport, &key, &[i as u8; 96]));
    }
    let mut hits = 0u64;
    for i in 0..200u64 {
        let key = format!("k{i}").into_bytes();
        if let Some(v) = secure.get(&mut transport, &key) {
            assert_eq!(v, vec![i as u8; 96], "escape at key {i}");
            hits += 1;
        }
    }
    assert!(hits > 0, "nothing survived a 40% tamper rate");
    assert!(secure.stats.integrity_failures > 0, "tampering never fired");
    assert_eq!(
        secure.stats.integrity_failures,
        server.byzantine_tampered(),
        "every tampered response must be caught, none must escape"
    );
    server.stop();
}

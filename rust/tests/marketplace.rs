//! End-to-end marketplace tests over real TCP: a broker daemon, two
//! producer agents, and a lease-aware consumer pool. Covers the full
//! grant → put → get → revoke → recover path, producer failure mid-run
//! (cache misses, never errors; no lost acknowledged writes on the
//! survivor), lease expiry provably shrinking the producer store, and
//! the cross-plane handshake refusals.

use memtrade::consumer::client::{KvTransport, SecureKv, DEAD_ROUTE};
use memtrade::core::config::BrokerConfig;
use memtrade::core::SimTime;
use memtrade::market::{
    BrokerServer, BrokerServerConfig, ProducerAgent, ProducerAgentConfig, RemotePool,
    RemotePoolConfig,
};
use memtrade::net::control::{
    server_handshake_patient, CtrlClient, CtrlRequest, CtrlResponse, CONTROL_MAGIC, DATA_MAGIC,
};
use memtrade::net::tcp::{KvClient, ProducerStoreServer};
use memtrade::net::wire::{read_frame_into_patient, write_frame, Request, Response};
use std::io::{BufReader, BufWriter};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const SLAB: u64 = 1 << 20; // 1 MB slabs: cheap grants, fast tests

fn broker_cfg(min_lease_ms: u64) -> BrokerConfig {
    BrokerConfig {
        slab_bytes: SLAB,
        min_lease: SimTime::from_millis(min_lease_ms),
        ..Default::default()
    }
}

fn server_cfg() -> BrokerServerConfig {
    BrokerServerConfig {
        tick: Duration::from_millis(20),
        producer_timeout: Duration::from_millis(400),
        // Stay on optimistic (reported-free) safety in tests: histories
        // are seconds old, far too short for the AR fit.
        forecast_min_samples: usize::MAX,
        ..Default::default()
    }
}

fn start_agent(broker: &BrokerServer, id: u64, capacity: u64) -> ProducerAgent {
    ProducerAgent::start(ProducerAgentConfig {
        producer: id,
        brokers: vec![broker.addr().to_string()],
        data_addr: "127.0.0.1:0".to_string(),
        advertise: None,
        capacity_bytes: capacity,
        harvest: false,
        heartbeat: Duration::from_millis(50),
        shards: 2,
        rate_bps: None,
        seed: id,
        ..Default::default()
    })
    .expect("agent start")
}

/// Spin until `cond` holds or `timeout` passes; true if it held.
fn wait_for(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    cond()
}

#[test]
fn marketplace_survives_producer_failure() {
    let broker = BrokerServer::start("127.0.0.1:0", broker_cfg(800), server_cfg()).unwrap();
    let mut agents =
        vec![start_agent(&broker, 1, 16 * SLAB), start_agent(&broker, 2, 16 * SLAB)];
    assert_eq!(broker.producer_count(), 2);

    // Lease more than one producer can hold, so slots span both.
    let mut pool = RemotePool::connect(RemotePoolConfig {
        consumer: 9,
        brokers: vec![broker.addr().to_string()],
        target_slabs: 24,
        min_slabs: 1,
        lease_ttl: Duration::from_millis(900),
        renew_margin: Duration::from_millis(400),
        maintain_every: Duration::from_millis(20),
        ..Default::default()
    })
    .unwrap();
    assert!(
        wait_for(Duration::from_secs(5), || {
            pool.maintain();
            pool.held_slabs() >= 20 && pool.distinct_endpoints().len() >= 2
        }),
        "pool never reached target capacity: {} slabs, endpoints {:?}",
        pool.held_slabs(),
        pool.live_endpoints()
    );
    let endpoints = pool.distinct_endpoints();
    assert!(
        endpoints.contains(&agents[0].data_addr().to_string())
            && endpoints.contains(&agents[1].data_addr().to_string()),
        "slots must span both producers: {endpoints:?}"
    );
    // Agents must have grown their stores to the broker's target.
    assert!(wait_for(Duration::from_secs(3), || {
        agents.iter().all(|a| {
            let max = a.store().map(|s| s.max_bytes()).unwrap_or(0) as u64;
            max == a.target_bytes() && max > 0
        })
    }));

    // Sustained traffic: store a working set, then read it back.
    let mut secure = SecureKv::with_iv_seed(Some([7u8; 16]), true, 1, 3);
    let n_keys = 1200u32;
    let value = vec![0xAB_u8; 256];
    let mut stored = Vec::new();
    for i in 0..n_keys {
        if secure.put(&mut pool, format!("key{i}").as_bytes(), &value) {
            stored.push(i);
        }
    }
    assert!(
        stored.len() as f64 >= n_keys as f64 * 0.9,
        "only {}/{n_keys} puts acknowledged",
        stored.len()
    );
    let mut hits = 0;
    for &i in &stored {
        if secure.get(&mut pool, format!("key{i}").as_bytes()).is_some() {
            hits += 1;
        }
    }
    assert!(
        hits as f64 >= stored.len() as f64 * 0.95,
        "pre-failure hits {hits}/{}",
        stored.len()
    );

    // Kill one producer mid-run. Its memory is gone; the marketplace
    // must degrade to cache misses and re-provision — never error.
    let dead_addr = agents[0].data_addr().to_string();
    agents[0].kill();
    let mut sweep_hits: Vec<bool> = Vec::new();
    for &i in &stored {
        sweep_hits.push(secure.get(&mut pool, format!("key{i}").as_bytes()).is_some());
    }
    let first_hits = sweep_hits.iter().filter(|&&h| h).count();
    assert!(first_hits > 0, "survivor data lost");
    assert!(first_hits < stored.len(), "dead producer's data cannot all survive");
    assert_eq!(secure.stats.integrity_failures, 0);

    // No lost acknowledged writes on the surviving producer: everything
    // that hit right after the failure keeps hitting.
    for (pos, &i) in stored.iter().enumerate() {
        let hit = secure.get(&mut pool, format!("key{i}").as_bytes()).is_some();
        if sweep_hits[pos] {
            assert!(hit, "acknowledged write key{i} lost on surviving producer");
        }
    }

    // Automatic re-provisioning: the broker sweeps the dead producer and
    // the pool refills from the survivor (16 slabs of capacity).
    assert!(
        wait_for(Duration::from_secs(5), || {
            pool.maintain();
            pool.held_slabs() >= 12
                && !pool.live_endpoints().contains(&dead_addr)
        }),
        "pool did not re-provision: {} slabs, endpoints {:?}",
        pool.held_slabs(),
        pool.live_endpoints()
    );
    assert!(pool.stats.slots_lost.get() > 0);
    assert!(pool.stats.rerequests.get() > 0);

    // Lost keys refill as cache writes and then hit again.
    let mut refilled = 0;
    for (pos, &i) in stored.iter().enumerate() {
        if !sweep_hits[pos]
            && secure.put(&mut pool, format!("key{i}").as_bytes(), &value)
        {
            refilled += 1;
        }
    }
    assert!(refilled > 0);
    let mut final_hits = 0;
    for &i in &stored {
        if secure.get(&mut pool, format!("key{i}").as_bytes()).is_some() {
            final_hits += 1;
        }
    }
    assert!(
        final_hits > first_hits,
        "recovery did not restore hit ratio: {final_hits} vs {first_hits}"
    );
    assert_eq!(secure.stats.integrity_failures, 0);

    drop(pool);
    agents.remove(1).stop();
    broker.stop();
}

#[test]
fn pool_batches_fan_out_per_producer_and_degrade_per_op_on_kill() {
    let broker = BrokerServer::start("127.0.0.1:0", broker_cfg(800), server_cfg()).unwrap();
    let mut agents =
        vec![start_agent(&broker, 1, 16 * SLAB), start_agent(&broker, 2, 16 * SLAB)];
    let mut pool = RemotePool::connect(RemotePoolConfig {
        consumer: 11,
        brokers: vec![broker.addr().to_string()],
        target_slabs: 24,
        min_slabs: 1,
        lease_ttl: Duration::from_secs(10),
        renew_margin: Duration::from_secs(2),
        maintain_every: Duration::from_millis(20),
        data_window: 2,
        ..Default::default()
    })
    .unwrap();
    assert!(wait_for(Duration::from_secs(5), || {
        pool.maintain();
        pool.held_slabs() >= 20 && pool.distinct_endpoints().len() >= 2
    }));
    assert!(wait_for(Duration::from_secs(3), || {
        agents.iter().all(|a| {
            let max = a.store().map(|s| s.max_bytes()).unwrap_or(0) as u64;
            max == a.target_bytes() && max > 0
        })
    }));

    // A batched working set: multi_put routes per key across both
    // producers' slots, fanning out one batch frame per producer.
    let mut secure = SecureKv::with_iv_seed(Some([8u8; 16]), true, 1, 4);
    let keys: Vec<Vec<u8>> = (0..400).map(|i| format!("bkey{i}").into_bytes()).collect();
    let vals: Vec<Vec<u8>> = (0..400).map(|i| vec![(i % 251) as u8; 128]).collect();
    let items: Vec<(&[u8], &[u8])> =
        keys.iter().zip(&vals).map(|(k, v)| (k.as_slice(), v.as_slice())).collect();
    let stored = secure.multi_put(&mut pool, &items);
    let n_stored = stored.iter().filter(|&&s| s).count();
    assert!(n_stored >= 360, "only {n_stored}/400 batched puts acknowledged");

    let key_refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
    let got = secure.multi_get(&mut pool, &key_refs);
    let mut hits = 0;
    for (i, g) in got.iter().enumerate() {
        if let Some(v) = g {
            assert_eq!(v, &vals[i], "batched op {i} returned wrong bytes");
            hits += 1;
        }
    }
    assert!(hits >= n_stored * 95 / 100, "batched hits {hits}/{n_stored}");
    assert_eq!(secure.stats.integrity_failures, 0);

    // Kill one producer: batched gets spanning both producers must
    // degrade *per op* — survivor ops still hit, dead ops are misses,
    // never an error and never a poisoned sibling.
    agents[0].kill();
    let got = secure.multi_get(&mut pool, &key_refs);
    let mut post_hits = 0;
    for (i, g) in got.iter().enumerate() {
        if let Some(v) = g {
            assert_eq!(v, &vals[i], "post-kill batched op {i} returned wrong bytes");
            post_hits += 1;
        }
    }
    assert!(post_hits > 0, "survivor's batched data lost");
    assert!(post_hits < n_stored, "dead producer's batched data cannot all survive");
    assert_eq!(secure.stats.integrity_failures, 0);

    // Batched deletes on the survivor's keys synchronize its store.
    let deleted = secure.multi_delete(&mut pool, &key_refs);
    assert_eq!(deleted.len(), 400);
    assert!(deleted.iter().any(|&d| d), "no batched delete reached the survivor");
    assert!(secure.is_empty());

    drop(pool);
    agents.remove(1).stop();
    broker.stop();
}

#[test]
fn lease_renewal_sustains_and_expiry_shrinks_store() {
    let broker = BrokerServer::start("127.0.0.1:0", broker_cfg(300), server_cfg()).unwrap();
    let agent = start_agent(&broker, 1, 16 * SLAB);

    // Lease 4 slabs directly (no pool, so nothing renews for us).
    let mut ctrl = CtrlClient::connect(broker.addr()).unwrap();
    let lease = {
        let mut granted = None;
        assert!(wait_for(Duration::from_secs(3), || {
            match ctrl
                .call(&CtrlRequest::RequestSlabs {
                    consumer: 9,
                    slabs: 4,
                    min_slabs: 4,
                    ttl_us: 500_000,
                    trace: 0,
                })
                .unwrap()
            {
                CtrlResponse::Grants { leases } => {
                    granted = Some(leases[0].clone());
                    true
                }
                _ => false,
            }
        }));
        granted.unwrap()
    };
    assert_eq!(lease.slab_bytes, SLAB);

    // The agent's next heartbeat grows the store to the leased bytes.
    assert!(
        wait_for(Duration::from_secs(3), || {
            agent.store().map(|s| s.max_bytes()).unwrap_or(0) as u64 == 4 * SLAB
        }),
        "store never grew to the lease: {} bytes",
        agent.store().map(|s| s.max_bytes()).unwrap_or(0)
    );
    // Leased memory accepts writes.
    let mut kv = KvClient::connect(agent.data_addr()).unwrap();
    assert!(kv.put(b"k", &[1, 2, 3]).unwrap());

    // Renewals keep it alive well past the original 500 ms expiry.
    for _ in 0..6 {
        std::thread::sleep(Duration::from_millis(100));
        let resp = ctrl
            .call(&CtrlRequest::Renew { consumer: 9, lease: lease.lease, trace: 0 })
            .unwrap();
        assert!(matches!(resp, CtrlResponse::Renewed { .. }), "{resp:?}");
    }
    assert_eq!(agent.store().map(|s| s.max_bytes()).unwrap_or(0) as u64, 4 * SLAB);

    // Stop renewing: expiry must provably shrink the producer store.
    assert!(
        wait_for(Duration::from_secs(3), || {
            agent.store().map(|s| s.max_bytes()).unwrap_or(1) == 0
        }),
        "lease expiry did not shrink the store"
    );
    // And the data went with it: a fresh GET misses, a PUT is rejected.
    assert_eq!(kv.get(b"k").unwrap(), None);
    assert!(!kv.put(b"again", &[4]).unwrap());
    // Renew-after-expiry is a clean refusal.
    let resp = ctrl
        .call(&CtrlRequest::Renew { consumer: 9, lease: lease.lease, trace: 0 })
        .unwrap();
    assert!(matches!(resp, CtrlResponse::Refused { .. }), "{resp:?}");

    agent.stop();
    broker.stop();
}

#[test]
fn zero_live_slots_put_get_delete_are_recorded_misses() {
    // Regression (flushed out by the chaos plane — the standard mix,
    // e.g. `memtrade chaos --seed 601 --mix standard`, drives the pool
    // through all-slots-dead windows): `route_put` used to return the
    // caller's raw round-robin hint when no slots were live. That hint
    // is an index in *SecureKv's* producer table, not the pool's slot
    // table — so the PUT could land on a dead, reused, or out-of-range
    // slot index. It must instead take the deterministic recorded-miss
    // path (DEAD_ROUTE).
    let broker = BrokerServer::start("127.0.0.1:0", broker_cfg(300), server_cfg()).unwrap();
    // No producers registered: the pool connects but holds nothing.
    let mut pool = RemotePool::connect(RemotePoolConfig {
        consumer: 9,
        brokers: vec![broker.addr().to_string()],
        target_slabs: 4,
        ..Default::default()
    })
    .unwrap();
    assert_eq!(pool.live_slots(), 0);
    assert_eq!(pool.route_put(b"any-key", 7), DEAD_ROUTE);

    // The full secure path: every operation is a clean miss, no panic,
    // no connection attempt to a phantom producer.
    let mut secure = SecureKv::with_iv_seed(Some([1u8; 16]), true, 1, 2);
    let t0 = Instant::now();
    assert!(!secure.put(&mut pool, b"k", b"v"));
    assert_eq!(secure.get(&mut pool, b"k"), None);
    assert!(!secure.delete(&mut pool, b"k"));
    assert!(t0.elapsed() < Duration::from_secs(5));
    assert!(pool.stats.dead_calls.get() >= 1, "PUT did not take the recorded-miss path");
    assert_eq!(pool.stats.io_errors.get(), 0);

    // The transport-level contract for dead-routed calls of each verb.
    assert_eq!(pool.call(DEAD_ROUTE, Request::Get { key: b"x".to_vec() }), Response::NotFound);
    assert_eq!(
        pool.call(DEAD_ROUTE, Request::Put { key: b"x".to_vec(), value: b"y".to_vec() }),
        Response::Rejected
    );
    assert_eq!(
        pool.call(DEAD_ROUTE, Request::Delete { key: b"x".to_vec() }),
        Response::Deleted(false)
    );
    broker.stop();
}

#[test]
fn stalled_producer_surfaces_as_bounded_miss_not_a_wedge() {
    // Regression (flushed out by the chaos plane — delay/drop schedules
    // like `memtrade chaos --seed 201 --mix data` stall responses
    // mid-stream): the pool's data clients used to read with no
    // timeout, so a producer that accepted a request and then went
    // silent wedged the consumer data path forever. The pool now bounds
    // every data call (`data_call_timeout`) and turns the stall into a
    // dead slot, i.e. a cache miss.
    let broker = BrokerServer::start(
        "127.0.0.1:0",
        broker_cfg(300),
        BrokerServerConfig {
            tick: Duration::from_millis(20),
            // The silent producer sends no heartbeats; keep it "alive"
            // broker-side for the whole test.
            producer_timeout: Duration::from_secs(30),
            forecast_min_samples: usize::MAX,
            ..Default::default()
        },
    )
    .unwrap();

    // A fake producer data plane: completes the handshake, reads
    // request frames, never answers.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let silent_addr = listener.local_addr().unwrap();
    listener.set_nonblocking(true).unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let silent = std::thread::spawn(move || {
        let mut conns = Vec::new();
        while !stop2.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let stop = stop2.clone();
                    conns.push(std::thread::spawn(move || {
                        stream.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
                        let mut reader = BufReader::new(stream.try_clone().unwrap());
                        let mut writer = BufWriter::new(stream);
                        let keep = || !stop.load(Ordering::Relaxed);
                        let shook =
                            server_handshake_patient(&mut reader, &mut writer, DATA_MAGIC, keep);
                        if !matches!(shook, Ok(Some(_))) {
                            return;
                        }
                        // Swallow requests; answer nothing, ever.
                        let mut frame = Vec::new();
                        loop {
                            match read_frame_into_patient(&mut reader, &mut frame, keep) {
                                Ok(true) => {}
                                _ => return,
                            }
                        }
                    }));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(2)),
            }
        }
        for c in conns {
            let _ = c.join();
        }
    });

    // Register the silent endpoint as a producer so the broker grants
    // leases on it.
    let mut ctrl = CtrlClient::connect(broker.addr()).unwrap();
    let resp = ctrl
        .call(&CtrlRequest::Register {
            producer: 1,
            capacity_gb: 0.25,
            endpoint: silent_addr.to_string(),
            free_bytes: 8 * SLAB,
        })
        .unwrap();
    assert!(matches!(resp, CtrlResponse::Registered { .. }), "{resp:?}");

    let mut pool = RemotePool::connect(RemotePoolConfig {
        consumer: 9,
        brokers: vec![broker.addr().to_string()],
        target_slabs: 4,
        lease_ttl: Duration::from_secs(10),
        renew_margin: Duration::from_secs(2),
        maintain_every: Duration::from_millis(50),
        data_call_timeout: Duration::from_millis(300),
        ..Default::default()
    })
    .unwrap();
    assert!(
        wait_for(Duration::from_secs(3), || {
            pool.maintain();
            pool.live_slots() > 0
        }),
        "pool never mounted the silent producer"
    );

    let mut secure = SecureKv::with_iv_seed(Some([9u8; 16]), true, 1, 1);
    let t0 = Instant::now();
    assert!(
        !secure.put(&mut pool, b"k", b"v"),
        "a write into a silent producer must fail as a miss"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "data path wedged on a stalled producer for {:?}",
        t0.elapsed()
    );
    assert!(pool.stats.io_errors.get() >= 1, "the stall was not surfaced as an I/O loss");
    assert_eq!(secure.stats.integrity_failures, 0);

    stop.store(true, Ordering::Relaxed);
    drop(pool);
    let _ = silent.join();
    broker.stop();
}

#[test]
fn mismatched_control_response_drops_the_connection() {
    // Regression (flushed out by the chaos plane — `duplicate` faults,
    // e.g. `memtrade chaos --seed 601 --mix standard`): a duplicated
    // control frame shifts every later response by one, so a pool that
    // *interprets* mismatched responses misreads renews as grants (and
    // vice versa) forever. A response that does not match the request
    // must be treated as a desynced stream: drop the connection and
    // reconnect fresh.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let fake_broker = std::thread::spawn(move || {
        let Ok((stream, _)) = listener.accept() else { return };
        stream.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        let keep = || !stop2.load(Ordering::Relaxed);
        let shook = server_handshake_patient(&mut reader, &mut writer, CONTROL_MAGIC, keep);
        if !matches!(shook, Ok(Some(_))) {
            return;
        }
        let mut frame = Vec::new();
        while matches!(read_frame_into_patient(&mut reader, &mut frame, keep), Ok(true)) {
            // Always the wrong answer: a Renewed ack nobody asked for.
            let resp = CtrlResponse::Renewed { lease: 0, ttl_us: 1 }.encode();
            if write_frame(&mut writer, &resp).is_err() {
                return;
            }
        }
    });

    let mut pool = RemotePool::connect(RemotePoolConfig {
        consumer: 9,
        brokers: vec![addr.to_string()],
        target_slabs: 4,
        ..Default::default()
    })
    .unwrap();
    // The initial refill asked for slabs and was answered with a renew
    // ack: the pool must flag the connection, not invent capacity.
    assert!(
        pool.stats.control_errors.get() >= 1,
        "mismatched control response was not treated as a desynced stream"
    );
    assert_eq!(pool.held_slabs(), 0);
    stop.store(true, Ordering::Relaxed);
    drop(pool);
    let _ = fake_broker.join();
}

#[test]
fn cross_plane_connections_fail_with_clear_errors() {
    let broker = BrokerServer::start("127.0.0.1:0", broker_cfg(300), server_cfg()).unwrap();
    // Data client dials the broker's control port.
    let err = KvClient::connect(broker.addr()).unwrap_err();
    assert!(
        err.to_string().contains("control plane"),
        "unhelpful cross-plane error: {err}"
    );

    let store = ProducerStoreServer::start("127.0.0.1:0", 1 << 20, None, 1).unwrap();
    // Control client dials a producer-store data port.
    let err = CtrlClient::connect(store.addr()).unwrap_err();
    assert!(
        err.to_string().contains("data plane"),
        "unhelpful cross-plane error: {err}"
    );
    store.stop();
    broker.stop();
}

//! Fixture tests for `memtrade lint` — one passing and one failing
//! example per rule — plus the self-check: the shipped tree must be
//! lint-clean, which is exactly what the CI `static-analysis` job
//! gates on via `memtrade lint`.
//!
//! Every fixture lives in a raw string, which also exercises the
//! tokenizer's reason for existing: rule patterns inside string
//! literals (like these fixtures, when the linter walks *this* file)
//! must never match.

use memtrade::analysis::{check_protocol_doc, lint_source, lint_tree, parse_manifest, Diagnostic};
use std::path::Path;

fn rules(diags: &[Diagnostic]) -> Vec<&'static str> {
    diags.iter().map(|d| d.rule).collect()
}

// ------------------------------------------------------- rule: wire-tags

const MANIFEST: &str = "\
# test registry
frame TAG_GET 1
frame TAG_PUT 2
metric METRIC_COUNTER 1
";

#[test]
fn wire_tags_pass_when_registered_and_unique() {
    let src = r#"
pub const TAG_GET: u8 = 1;
pub const TAG_PUT: u8 = 2;
const METRIC_COUNTER: u8 = 1; // same value, different namespace: fine
"#;
    let diags = lint_source("src/net/wire.rs", src, Some(MANIFEST));
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn wire_tags_fail_on_reuse_with_both_names_printed() {
    // A "protocol bump" that reuses TAG_GET's value for a new frame.
    let src = r#"
pub const TAG_GET: u8 = 1;
pub const TAG_PUT: u8 = 2;
pub const TAG_EVICT_HINT: u8 = 1;
"#;
    let diags = lint_source("src/net/wire.rs", src, Some(MANIFEST));
    assert!(rules(&diags).contains(&"wire-tags"), "{diags:?}");
    let collision = diags.iter().find(|d| d.msg.contains("collision")).unwrap();
    assert!(
        collision.msg.contains("TAG_GET") && collision.msg.contains("TAG_EVICT_HINT"),
        "colliding frame names must be printed: {}",
        collision.msg
    );
    assert_eq!(collision.line, 4, "diagnostic anchors the new (colliding) tag");
}

// --------------------------------------------------- rule: decode-bounds

#[test]
fn decode_bounds_pass_when_count_is_checked() {
    let src = r#"
fn decode_batch(buf: &[u8], off: usize) -> Vec<Op> {
    let n = read_u32(buf) as usize;
    if n > MAX_BATCH_OPS || n > (buf.len() - off) / 4 {
        return Vec::new();
    }
    let mut ops = Vec::with_capacity(n);
    ops
}
"#;
    let diags = lint_source("src/net/wire.rs", src, None);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn decode_bounds_fail_on_unchecked_count() {
    // The classic allocation bomb: attacker-declared count drives
    // reservation before any byte of the payload exists.
    let src = r#"
fn decode_batch(buf: &[u8]) -> Vec<Op> {
    let n = read_u32(buf) as usize;
    let mut ops = Vec::with_capacity(n);
    ops
}
"#;
    let diags = lint_source("src/net/wire.rs", src, None);
    assert_eq!(rules(&diags), ["decode-bounds"], "{diags:?}");
    assert_eq!(diags[0].line, 4);
    assert!(diags[0].msg.contains('n'), "{}", diags[0].msg);
}

// ------------------------------------------------------------ rule: clock

#[test]
fn clock_pass_in_allowlisted_daemon_file() {
    let src = "fn maintain(&mut self) { self.next = Instant::now(); }";
    let diags = lint_source("src/market/remote_pool.rs", src, None);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn clock_fail_in_lease_state_machine() {
    // market/lease.rs is the file the rule exists for: lease expiry
    // must be driven by the caller's clock (simulator or daemon).
    let src = "fn expired(&self) -> bool { Instant::now() > self.deadline }";
    let diags = lint_source("src/market/lease.rs", src, None);
    assert_eq!(rules(&diags), ["clock"], "{diags:?}");
    let sys = "fn stamp(&self) -> u64 { let t = SystemTime::now(); to_micros(t) }";
    let diags = lint_source("src/market/replication.rs", sys, None);
    assert_eq!(rules(&diags), ["clock"], "{diags:?}");
}

// ------------------------------------------------------- rule: lock-order

#[test]
fn lock_order_pass_on_ascending_acquisition() {
    let src = r#"
fn shrink_all(&self) {
    let guards: Vec<_> = (0..self.num_shards()).map(|i| self.lock_shard(i)).collect();
    drop(guards);
}
fn one(&self, key: &[u8]) -> bool {
    let g = self.lock_shard(self.shard_index(key));
    g.contains(key)
}
"#;
    let diags = lint_source("src/kv/sharded.rs", src, None);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn lock_order_fail_on_second_lock_while_guard_live() {
    // Descending acquisition: deadlocks against the ascending batch
    // path the moment the two run concurrently.
    let src = r#"
fn migrate(&self, from: usize, to: usize) {
    let src_guard = self.lock_shard(from);
    let dst_guard = self.lock_shard(to);
    drop((src_guard, dst_guard));
}
"#;
    let diags = lint_source("src/kv/sharded.rs", src, None);
    assert_eq!(rules(&diags), ["lock-order"], "{diags:?}");
    assert_eq!(diags[0].line, 4, "the second acquisition is the violation");
}

// --------------------------------------------------------- rule: no-alloc

#[test]
fn no_alloc_pass_for_buffer_reuse() {
    let src = r#"
// lint: no-alloc
fn encode_into(&self, out: &mut Vec<u8>) {
    out.push(TAG);
    out.extend_from_slice(&self.key);
}
fn unmarked() -> Vec<u8> {
    self.key.to_vec() // fine: not a marked hot path
}
"#;
    let diags = lint_source("src/net/wire.rs", src, None);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn no_alloc_fail_on_per_call_allocation() {
    let src = r#"
// lint: no-alloc
fn record(&self, v: u64) {
    let label = format!("bucket{}", bucket_index(v));
    self.emit(&label, v.to_string());
}
"#;
    let diags = lint_source("src/metrics/hist.rs", src, None);
    assert_eq!(rules(&diags), ["no-alloc", "no-alloc"], "{diags:?}");
    assert!(diags[0].msg.contains("format!"), "{}", diags[0].msg);
    assert!(diags[1].msg.contains("to_string"), "{}", diags[1].msg);
}

// ----------------------------------------------------------- rule: safety

#[test]
fn safety_pass_with_adjacent_justification() {
    let src = r#"
fn words(&self) -> u64 {
    // SAFETY: the slot array is 8-word aligned and `idx` was taken
    // modulo its length above, so the read cannot go out of bounds.
    unsafe { *self.slots.get_unchecked(idx) }
}
"#;
    let diags = lint_source("src/trace/mod.rs", src, None);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn safety_fail_without_justification() {
    let src = r#"
fn words(&self) -> u64 {
    unsafe { *self.slots.get_unchecked(idx) }
}
"#;
    let diags = lint_source("src/trace/mod.rs", src, None);
    assert_eq!(rules(&diags), ["safety"], "{diags:?}");
    assert_eq!(diags[0].line, 3);
}

// ----------------------------------------------------- rule: protocol-doc

#[test]
fn protocol_doc_pass_when_every_tag_line_carries_its_value() {
    let mut diags = Vec::new();
    let manifest = parse_manifest("m", MANIFEST, &mut diags);
    assert!(diags.is_empty(), "{diags:?}");
    let doc = "\
# Wire spec
| `TAG_GET` | 1 | read one key |
| `TAG_PUT` | 2 | write one key |
Metric sets lead with `METRIC_COUNTER` (1).
";
    check_protocol_doc(doc, &manifest, &mut diags);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn protocol_doc_fail_on_missing_tag_and_renumbered_value() {
    let mut diags = Vec::new();
    let manifest = parse_manifest("m", MANIFEST, &mut diags);
    // TAG_PUT is never mentioned; TAG_GET's first naming line says 11,
    // which must not substring-match the registered value 1.
    let doc = "\
| `TAG_GET` | 11 | read one key |
Metric sets lead with `METRIC_COUNTER` (1).
";
    check_protocol_doc(doc, &manifest, &mut diags);
    assert_eq!(rules(&diags), ["protocol-doc", "protocol-doc"], "{diags:?}");
    let renumbered = &diags[0];
    assert!(
        renumbered.msg.contains("TAG_GET") && renumbered.msg.contains("without its wire value"),
        "{renumbered:?}"
    );
    assert_eq!(renumbered.line, 1, "anchors the line that names the tag");
    assert!(
        diags[1].msg.contains("TAG_PUT") && diags[1].msg.contains("never mentions"),
        "{:?}",
        diags[1]
    );
}

// ----------------------------------------------------- rule: syscall-site

#[test]
fn syscall_site_pass_in_allowlisted_file_and_with_marker() {
    let src = r#"
pub fn raise_nofile_limit() -> u64 {
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
    }
    0
}
"#;
    let diags = lint_source("src/util/bench.rs", src, None);
    assert!(diags.is_empty(), "{diags:?}");
    let marked = r#"
// lint: allow-syscall — one-off FFI probe, justified in DESIGN.md
extern "C" {
    fn getpid() -> i32;
}
"#;
    let diags = lint_source("src/figures/probe.rs", marked, None);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn syscall_site_fail_outside_the_allowlist() {
    // A market module sprouting its own libc binding would make the
    // loop's syscalls-per-op estimate a lie; the rule names the rule.
    let src = r#"
fn now_ns() -> u64 {
    extern "C" {
        fn clock_gettime(clk: i32, tp: *mut Timespec) -> i32;
    }
    0
}
"#;
    let diags = lint_source("src/market/lease.rs", src, None);
    assert_eq!(rules(&diags), ["syscall-site"], "{diags:?}");
    assert_eq!(diags[0].line, 3, "anchors the extern declaration");
    assert!(diags[0].msg.contains("allow-syscall"), "{}", diags[0].msg);
}

// ------------------------------------------------- tokenizer adversaria

#[test]
fn patterns_inside_strings_and_comments_never_match() {
    let src = r##"
// Instant::now() in a comment.
fn doc() -> &'static str {
    let a = "Instant::now() in a string";
    let b = r#"unsafe { lock_shard(0) } in a raw string"#;
    concat(a, b)
}
"##;
    let diags = lint_source("src/market/lease.rs", src, None);
    assert!(diags.is_empty(), "{diags:?}");
}

// ------------------------------------------------------------ self-check

/// The shipped tree is lint-clean. This is the same walk the CI
/// `static-analysis` job performs via `memtrade lint`; keeping it as a
/// test means `cargo test` alone catches a violation before CI does.
#[test]
fn shipped_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_tree(root).expect("lint walk failed");
    assert!(
        report.files >= 80,
        "suspiciously few files walked: {}",
        report.files
    );
    assert!(
        report.is_clean(),
        "shipped tree has lint violations:\n{}",
        report
            .diagnostics
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

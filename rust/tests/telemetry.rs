//! End-to-end tests of the telemetry spine over real TCP: `StatsQuery`
//! returns live per-producer p99 + ops/sec from a running broker +
//! agents + pool topology, each agent's stats endpoint serves its data
//! plane's live registry, and — the loop this PR closes — a producer
//! whose store is *observed* slow loses placement share, regardless of
//! what it self-reports.

use memtrade::consumer::client::SecureKv;
use memtrade::core::config::BrokerConfig;
use memtrade::core::SimTime;
use memtrade::market::{
    BrokerServer, BrokerServerConfig, ProducerAgent, ProducerAgentConfig,
    RemotePool, RemotePoolConfig,
};
use memtrade::metrics::MetricSet;
use memtrade::net::control::{CtrlClient, CtrlRequest, CtrlResponse};
use memtrade::net::faults::{FaultPlan, FaultSpec};
use memtrade::net::tcp::KvClient;
use std::time::{Duration, Instant};

const SLAB: u64 = 1 << 20;

fn broker_cfg() -> BrokerConfig {
    BrokerConfig {
        slab_bytes: SLAB,
        min_lease: SimTime::from_millis(800),
        ..Default::default()
    }
}

fn server_cfg() -> BrokerServerConfig {
    BrokerServerConfig {
        tick: Duration::from_millis(20),
        producer_timeout: Duration::from_secs(30),
        forecast_min_samples: usize::MAX,
        ..Default::default()
    }
}

fn start_agent(
    broker: &BrokerServer,
    id: u64,
    capacity: u64,
    data_faults: Option<FaultPlan>,
) -> ProducerAgent {
    ProducerAgent::start(ProducerAgentConfig {
        producer: id,
        brokers: vec![broker.addr().to_string()],
        data_addr: "127.0.0.1:0".to_string(),
        capacity_bytes: capacity,
        heartbeat: Duration::from_millis(50),
        shards: 2,
        seed: id,
        data_faults,
        ..Default::default()
    })
    .expect("agent start")
}

fn query_stats(addr: std::net::SocketAddr) -> (u64, MetricSet) {
    let mut ctrl = CtrlClient::connect(addr).expect("stats dial");
    match ctrl.call(&CtrlRequest::StatsQuery).expect("stats call") {
        CtrlResponse::Stats { uptime_us, metrics } => (uptime_us, metrics),
        other => panic!("unexpected stats reply: {other:?}"),
    }
}

#[test]
fn stats_query_reports_live_per_producer_telemetry() {
    let broker = BrokerServer::start("127.0.0.1:0", broker_cfg(), server_cfg()).unwrap();
    let agents =
        vec![start_agent(&broker, 1, 16 * SLAB, None), start_agent(&broker, 2, 16 * SLAB, None)];
    // More than one producer can hold, so live slots span both and
    // traffic reaches both data planes.
    let mut pool = RemotePool::connect(RemotePoolConfig {
        consumer: 9,
        brokers: vec![broker.addr().to_string()],
        target_slabs: 24,
        min_slabs: 1,
        lease_ttl: Duration::from_secs(10),
        renew_margin: Duration::from_secs(2),
        maintain_every: Duration::from_millis(20),
        ..Default::default()
    })
    .unwrap();

    let mut secure = SecureKv::with_iv_seed(Some([7u8; 16]), true, 1, 3);
    let value = vec![0xAB_u8; 256];
    // Drive traffic until the broker's StatsQuery shows *observed* p99
    // and throughput for both producers (flows: store op_us histogram →
    // agent heartbeat window delta → broker registry → StatsQuery).
    let deadline = Instant::now() + Duration::from_secs(15);
    let (mut i, mut live) = (0u32, None);
    while Instant::now() < deadline && live.is_none() {
        pool.maintain();
        for _ in 0..40 {
            let key = format!("key{}", i % 500);
            i += 1;
            if secure.get(&mut pool, key.as_bytes()).is_none() {
                let _ = secure.put(&mut pool, key.as_bytes(), &value);
            }
        }
        let (_, m) = query_stats(broker.addr());
        let seen = [1u64, 2].iter().all(|id| {
            m.gauge(&format!("producer.{id}.observed_p99_us")).unwrap_or(0) > 0
                && m.gauge(&format!("producer.{id}.ops_per_sec")).is_some()
        });
        if seen {
            live = Some(m);
        }
    }
    let m = live.expect("broker never reported observed telemetry for both producers");
    assert_eq!(m.gauge("market.producers"), Some(2));
    assert!(m.counter("ctrl.heartbeats").unwrap_or(0) > 0);
    assert!(m.gauge("market.active_leases").unwrap_or(0) > 0);
    assert!(m.counter("broker.leases_granted").unwrap_or(0) > 0);

    // Each agent's own stats endpoint serves the live data-plane
    // registry: per-op service latency, shard-lock holds, store state.
    for a in &agents {
        let stats_addr = a.stats_addr().expect("agent stats endpoint");
        let (uptime_us, am) = query_stats(stats_addr);
        assert!(uptime_us > 0);
        assert!(
            am.histogram("data.op_us").expect("op_us histogram").count() > 0,
            "agent {} served no observed ops",
            a.data_addr()
        );
        assert!(am.histogram("data.shard.lock_hold_us").unwrap().count() > 0);
        assert!(am.counter("data.ops").unwrap_or(0) > 0);
        assert!(am.counter("agent.heartbeats").unwrap_or(0) > 0);
        assert!(am.gauge("store.max_bytes").unwrap_or(0) > 0);
    }

    // The consumer side of the same plane.
    let pm = pool.metrics();
    assert!(pm.counter("pool.grants").unwrap_or(0) > 0);
    assert!(pm.histogram("pool.data_call_us").unwrap().count() > 0);
    let sm = secure.metrics();
    assert!(sm.histogram("secure.op_us").unwrap().count() > 0);
    assert!(sm.histogram("secure.seal_ns").unwrap().count() > 0);

    drop(pool);
    for a in agents {
        a.stop();
    }
    broker.stop();
}

#[test]
fn observed_latency_shifts_placement_away_from_slow_producer() {
    let broker = BrokerServer::start("127.0.0.1:0", broker_cfg(), server_cfg()).unwrap();
    // Producer 1 is healthy. Producer 2's data plane is artificially
    // slow: every response write stalls up to 8 ms (a chaos delay
    // plan). Both self-report identical free capacity and headroom —
    // only *observed* latency separates them.
    let slow_plan = FaultPlan::new(
        42,
        FaultSpec::default(),
        FaultSpec { delay_p: 1.0, delay_max_ms: 8, ..Default::default() },
    );
    let fast = start_agent(&broker, 1, 16 * SLAB, None);
    let slow = start_agent(&broker, 2, 16 * SLAB, Some(slow_plan.clone()));

    // Drive observable traffic at both data planes directly (GET misses
    // are served — and measured — even with zero leased budget).
    let mut fast_client = KvClient::connect(fast.data_addr()).unwrap();
    let mut slow_client = KvClient::connect(slow.data_addr()).unwrap();
    let deadline = Instant::now() + Duration::from_secs(20);
    let mut ready = false;
    while Instant::now() < deadline && !ready {
        for i in 0..20u32 {
            let key = format!("probe{i}");
            let _ = fast_client.get(key.as_bytes());
            let _ = slow_client.get(key.as_bytes());
        }
        let (_, m) = query_stats(broker.addr());
        let fast_p99 = m.gauge("producer.1.observed_p99_us").unwrap_or(0);
        let slow_p99 = m.gauge("producer.2.observed_p99_us").unwrap_or(0);
        // The injected stall is ≥ milliseconds; the healthy localhost
        // store serves in microseconds.
        ready = fast_p99 > 0 && fast_p99 < 2_000 && slow_p99 >= 2_000;
    }
    assert!(ready, "broker never observed the latency gap through heartbeats");
    assert!(slow_plan.counters().delays.get() > 0, "chaos delays not injected/counted");

    // A fresh consumer asks for capacity both producers could serve.
    // Placement must rank by observed tail latency: every grant lands
    // on the fast producer.
    let mut ctrl = CtrlClient::connect(broker.addr()).unwrap();
    for round in 0..3 {
        let resp = ctrl
            .call(&CtrlRequest::RequestSlabs {
                consumer: 77 + round,
                slabs: 4,
                min_slabs: 1,
                ttl_us: 60_000_000,
                trace: 0,
            })
            .unwrap();
        let CtrlResponse::Grants { leases } = resp else { panic!("{resp:?}") };
        assert!(!leases.is_empty());
        for lease in &leases {
            assert_eq!(
                lease.producer, 1,
                "round {round}: observed-slow producer won placement: {leases:?}"
            );
        }
    }

    fast.stop();
    slow.stop();
    broker.stop();
}

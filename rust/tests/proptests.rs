//! Property-based tests (randomized invariants; proptest is unavailable
//! offline so generation uses the crate's own deterministic RNG across
//! many seeds). Each property runs hundreds of randomized cases.

use memtrade::broker::placement::{rank, ConsumerRequest, ProducerState};
use memtrade::core::config::PlacementWeights;
use memtrade::core::{ConsumerId, ProducerId, SimTime};
use memtrade::crypto::aes::Aes128;
use memtrade::crypto::secure::Envelope;
use memtrade::crypto::sha256::sha256;
use memtrade::kv::KvStore;
use memtrade::mem::{GuestMemory, SwapDevice};
use memtrade::runtime::arima_fallback as fb;
use memtrade::util::avl::WindowedDist;
use memtrade::util::rng::Rng;
use memtrade::util::token_bucket::TokenBucket;

#[test]
fn prop_aes_round_trip_random() {
    let mut rng = Rng::new(101);
    for case in 0..300 {
        let mut key = [0u8; 16];
        let mut iv = [0u8; 16];
        for b in key.iter_mut().chain(iv.iter_mut()) {
            *b = rng.next_u64() as u8;
        }
        let len = rng.below(4096) as usize;
        let pt: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let aes = Aes128::new(&key);
        let ct = aes.cbc_encrypt(&iv, &pt);
        assert_eq!(ct.len() % 16, 0, "case {case}");
        assert_eq!(aes.cbc_decrypt(&iv, &ct).unwrap(), pt, "case {case}");
        // Wrong key fails to round-trip (padding check or wrong bytes).
        let mut bad_key = key;
        bad_key[0] ^= 1;
        let wrong = Aes128::new(&bad_key).cbc_decrypt(&iv, &ct);
        assert!(wrong.is_none() || wrong.unwrap() != pt, "case {case}");
    }
}

#[test]
fn prop_envelope_tamper_always_detected() {
    let mut rng = Rng::new(102);
    for case in 0..200 {
        let mut env = Envelope::with_iv_seed(Some([case as u8; 16]), true, case);
        let len = 1 + rng.below(2048) as usize;
        let value: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let sealed = env.seal(&value, 0);
        // Flip one random bit anywhere in the producer-visible bytes.
        let mut tampered = sealed.value_p.clone();
        let pos = rng.below(tampered.len() as u64) as usize;
        tampered[pos] ^= 1 << rng.below(8);
        assert!(env.open(&tampered, &sealed.meta).is_err(), "case {case} pos {pos}");
        // Untampered opens fine.
        assert_eq!(env.open(&sealed.value_p, &sealed.meta).unwrap(), value);
    }
}

#[test]
fn prop_sha256_avalanche() {
    let mut rng = Rng::new(103);
    for _ in 0..100 {
        let len = 1 + rng.below(512) as usize;
        let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let h1 = sha256(&data);
        let mut flipped = data.clone();
        let pos = rng.below(len as u64) as usize;
        flipped[pos] ^= 1;
        let h2 = sha256(&flipped);
        let diff_bits: u32 = h1
            .iter()
            .zip(&h2)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert!(diff_bits > 80, "weak avalanche: {diff_bits} bits");
    }
}

#[test]
fn prop_kv_accounting_invariants() {
    let mut rng = Rng::new(104);
    for seed in 0..20 {
        let max = (64 + rng.below(512)) as usize * 1024;
        let mut kv = KvStore::new(max, seed);
        for _ in 0..3000 {
            let k = format!("key{}", rng.below(200));
            match rng.below(4) {
                0..=1 => {
                    kv.put(k.as_bytes(), &vec![0u8; 1 + rng.below(3000) as usize]);
                }
                2 => {
                    let _ = kv.get(k.as_bytes());
                }
                _ => {
                    kv.delete(k.as_bytes());
                }
            }
            assert!(kv.used_bytes() <= kv.max_bytes());
            assert!(kv.live_bytes() <= kv.used_bytes());
            assert!(kv.fragmentation() >= 1.0 - 1e-12);
        }
    }
}

#[test]
fn prop_windowed_dist_matches_oracle() {
    let mut rng = Rng::new(105);
    for seed in 0..10 {
        let window_s = 10 + rng.below(200);
        let mut d = WindowedDist::new(SimTime::from_secs(window_s));
        let mut log: Vec<(u64, f64)> = Vec::new();
        for step in 0..800u64 {
            let v = (rng.normal(50.0, 20.0) * 4.0).round() / 4.0;
            d.insert(SimTime::from_secs(step), v);
            log.push((step, v));
            if step % 37 == 0 {
                let cutoff = step.saturating_sub(window_s);
                let mut live: Vec<f64> = log
                    .iter()
                    .filter(|&&(t, _)| t >= cutoff)
                    .map(|&(_, v)| v)
                    .collect();
                live.sort_by(|a, b| a.partial_cmp(b).unwrap());
                assert_eq!(d.len(), live.len(), "seed {seed} step {step}");
                for q in [0.0, 0.5, 0.99, 1.0] {
                    let k = ((q * live.len() as f64).ceil() as usize)
                        .saturating_sub(1)
                        .min(live.len() - 1);
                    assert_eq!(
                        d.quantile(q).unwrap(),
                        live[k],
                        "seed {seed} step {step} q {q}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_token_bucket_never_over_admits() {
    let mut rng = Rng::new(106);
    for seed in 0..20 {
        let rate = 1_000 + rng.below(1_000_000);
        let burst = 100 + rng.below(100_000);
        let mut tb = TokenBucket::new(rate, burst);
        let mut admitted = 0u64;
        let mut now = SimTime::ZERO;
        for _ in 0..2_000 {
            now += SimTime::from_micros(rng.below(5_000));
            let req = 1 + rng.below(burst);
            if tb.try_consume(now, req) {
                admitted += req;
            }
        }
        let bound = burst as f64 + rate as f64 * now.as_secs_f64() + 1.0;
        assert!(admitted as f64 <= bound, "seed {seed}: {admitted} > {bound}");
    }
}

#[test]
fn prop_placement_never_exceeds_grantable_and_orders_by_cost() {
    let mut rng = Rng::new(107);
    for case in 0..200 {
        let n = 1 + rng.below(50) as usize;
        let states: Vec<ProducerState> = (0..n)
            .map(|i| ProducerState {
                producer: ProducerId(i as u64 + 1),
                free_slabs: rng.below(256) as u32,
                predicted_safe_slabs: rng.below(256) as u32,
                cpu_headroom: rng.f64(),
                bandwidth_headroom: rng.f64(),
                latency_us: rng.below(5_000),
                reputation: rng.f64(),
            })
            .collect();
        let req = ConsumerRequest {
            consumer: ConsumerId(1),
            slabs: 1 + rng.below(512) as u32,
            min_slabs: 1,
            lease: SimTime::from_hours(1),
            max_price_per_slab_hour: None,
            latency_us_to: Default::default(),
            weights: None,
        };
        let w = PlacementWeights::default();
        let ranked = rank(&states, &req, &w);
        // Every ranked producer can actually grant something.
        for s in &ranked {
            assert!(s.grantable_slabs() > 0, "case {case}");
            assert!(s.grantable_slabs() <= s.free_slabs);
            assert!(s.grantable_slabs() <= s.predicted_safe_slabs);
        }
        // Ordering is by non-decreasing cost.
        let max_free = states.iter().map(|s| s.free_slabs).max().unwrap_or(0);
        let costs: Vec<f64> = ranked
            .iter()
            .map(|s| memtrade::broker::placement::cost(s, &w, max_free))
            .collect();
        for pair in costs.windows(2) {
            assert!(pair[0] <= pair[1] + 1e-12, "case {case}: {costs:?}");
        }
    }
}

#[test]
fn prop_guest_memory_page_conservation() {
    let mut rng = Rng::new(108);
    for seed in 0..15 {
        let mut g = GuestMemory::new(
            256 << 20,
            128 << 20,
            1 << 20,
            SwapDevice::Ssd,
            Some(SimTime::from_secs(30 + rng.below(300))),
            seed,
        );
        let app_pages = g.app_pages();
        let mut now = SimTime::ZERO;
        for _ in 0..500 {
            now += SimTime::from_secs(rng.below(20));
            match rng.below(5) {
                0 => {
                    g.set_cgroup_limit(rng.below(256 << 20), now);
                }
                1 => {
                    g.disable_cgroup_limit();
                }
                2 => {
                    g.prefetch(rng.below(64 << 20), now);
                }
                3 => {
                    g.tick(now);
                }
                _ => {
                    let p = rng.below(app_pages as u64) as u32;
                    g.access(p, now);
                }
            }
            // Conservation: every app page is exactly one of resident,
            // in Silo, or on disk.
            let total = g.rss_pages() + g.silo_pages() + g.disk_pages();
            assert_eq!(total, app_pages, "seed {seed}");
        }
    }
}

#[test]
fn prop_forecast_safe_never_exceeds_capacity() {
    let mut rng = Rng::new(109);
    for case in 0..100 {
        let w = 16 + rng.below(288) as usize;
        let cap = rng.uniform(1.0, 128.0) as f32;
        let series: Vec<f32> = (0..w)
            .map(|_| rng.uniform(0.0, cap as f64 * 1.2) as f32)
            .collect();
        let r = fb::forecast_one(&series, cap, 4, 12);
        for h in 0..12 {
            assert!(r.pred[h] >= 0.0 && r.pred[h] <= cap, "case {case}");
            assert!(r.safe[h] >= 0.0 && r.safe[h] <= cap, "case {case}");
            assert!(r.safe[h] <= cap - r.pred[h] + 1e-3, "case {case}");
        }
        assert!(r.sigma >= 0.0);
    }
}

#[test]
fn prop_lease_log_prefix_then_suffix_replay_equals_whole_log() {
    // The warm-standby contract: a replica that applied a log prefix,
    // drained its accounting queues (sweeps, billing, producer acks —
    // everything a live standby does between polls), and then applied
    // the suffix must hold the same *active* lease book as a replica
    // that replayed the whole log in one sitting. Event timestamps are
    // identical on both sides (the wire carries remaining TTLs, so
    // apply time is what sets expiries).
    use memtrade::market::{LeaseEvent, LeaseTable};

    // Normalized projection of the live book: terminal records are
    // garbage-collected by producer acks, so only active leases are
    // comparable — and they are exactly what a takeover must preserve.
    fn active_snapshot(t: &LeaseTable) -> Vec<(u64, u64, u64, u32, u64, u64)> {
        let mut v: Vec<_> = t
            .active()
            .map(|l| (l.id, l.consumer, l.producer, l.slabs, l.slab_bytes, l.expiry_us))
            .collect();
        v.sort_unstable();
        v
    }

    let mut rng = Rng::new(112);
    for case in 0..150 {
        let n = 20 + rng.below(180) as usize;
        let mut now = 0u64;
        // Grant ids are monotone, like the real grantor's — an id is
        // never reissued. Non-grant events target a granted id most of
        // the time and occasionally an unknown one (a log gap).
        let mut next_lease = 0u64;
        let mut log: Vec<(u64, LeaseEvent)> = Vec::with_capacity(n);
        for _ in 0..n {
            now += rng.below(400);
            let lease = if next_lease == 0 || rng.below(10) == 0 {
                next_lease + 1 + rng.below(5) // unknown / gapped id
            } else {
                1 + rng.below(next_lease)
            };
            let ev = match rng.below(10) {
                0..=3 => {
                    next_lease += 1;
                    LeaseEvent::Granted {
                        lease: next_lease,
                        consumer: 100 + rng.below(6),
                        producer: 1 + rng.below(4),
                        slabs: 1 + rng.below(8) as u32,
                        slab_bytes: 1 << 20,
                        price_nd_per_slab_hour: rng.below(1_000) as i64,
                        ttl_us: 200 + rng.below(3_000),
                    }
                }
                4..=5 => LeaseEvent::Renewed { lease, ttl_us: 200 + rng.below(3_000) },
                6 => LeaseEvent::Released { lease },
                7 => LeaseEvent::Revoked { lease },
                8 => LeaseEvent::Expired { lease },
                _ => {
                    let producer = 1 + rng.below(4);
                    if rng.below(2) == 0 {
                        LeaseEvent::ProducerUp {
                            producer,
                            endpoint: format!("127.0.0.1:{}", 7000 + producer),
                            capacity_gb: 1.0,
                        }
                    } else {
                        LeaseEvent::ProducerDown { producer }
                    }
                }
            };
            log.push((now, ev));
        }

        let mut whole = LeaseTable::default();
        for (t, ev) in &log {
            whole.apply_event(ev, *t);
        }

        let split = rng.below(log.len() as u64 + 1) as usize;
        let mut pieced = LeaseTable::default();
        for (t, ev) in &log[..split] {
            pieced.apply_event(ev, *t);
        }
        // Everything a live standby does between replication polls.
        let t_split = log.get(split.saturating_sub(1)).map(|(t, _)| *t).unwrap_or(0);
        let _ = pieced.sweep_expired(t_split);
        let _ = pieced.take_ended();
        for producer in 1..=4 {
            let _ = pieced.take_ended_unacked(producer);
        }
        for (t, ev) in &log[split..] {
            pieced.apply_event(ev, *t);
        }

        // Lapse what is overdue on both sides before comparing: the
        // mid-replay sweep already expired some of `pieced`'s book, and
        // parity means `whole` expires exactly the same leases when its
        // own sweep runs.
        let _ = whole.sweep_expired(now);
        let _ = pieced.sweep_expired(now);

        assert_eq!(
            active_snapshot(&whole),
            active_snapshot(&pieced),
            "case {case}: split {split}/{} diverged",
            log.len()
        );
        assert_eq!(whole.active_count(), pieced.active_count(), "case {case}");
        for producer in 1..=4u64 {
            assert_eq!(
                whole.producer_target_bytes(producer),
                pieced.producer_target_bytes(producer),
                "case {case}: producer {producer} target bytes diverged"
            );
        }
    }
}

#[test]
fn prop_wire_codec_round_trip_random() {
    use memtrade::net::wire::{Request, Response};
    let mut rng = Rng::new(110);
    for _ in 0..500 {
        let klen = rng.below(64) as usize;
        let vlen = rng.below(4096) as usize;
        let key: Vec<u8> = (0..klen).map(|_| rng.next_u64() as u8).collect();
        let value: Vec<u8> = (0..vlen).map(|_| rng.next_u64() as u8).collect();
        let reqs = [
            Request::Get { key: key.clone() },
            Request::Put { key: key.clone(), value: value.clone() },
            Request::Delete { key },
            Request::Ping,
        ];
        for r in reqs {
            assert_eq!(Request::decode(&r.encode()).unwrap(), r);
        }
        let resps = [
            Response::Value(value),
            Response::NotFound,
            Response::Throttled { retry_after_us: rng.next_u64() },
        ];
        for r in resps {
            assert_eq!(Response::decode(&r.encode()).unwrap(), r);
        }
    }
}

#[test]
fn prop_metrics_histogram_concurrent_record_merge_conserves_count() {
    use memtrade::metrics::Histogram;
    use std::sync::Arc;
    for seed in 0..4u64 {
        // 8 threads record deterministic per-thread sequences into one
        // shared histogram AND into private ones; the shared counts
        // must equal the merge of the private counts, bucket by bucket.
        let shared = Arc::new(Histogram::new());
        let handles: Vec<_> = (0..8u64)
            .map(|t| {
                let shared = shared.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::new(seed * 100 + t);
                    let local = Histogram::new();
                    for _ in 0..5_000 {
                        let v = rng.below(1 << 40);
                        shared.record(v);
                        local.record(v);
                    }
                    local.snapshot()
                })
            })
            .collect();
        let mut merged = memtrade::metrics::HistogramSnapshot::default();
        for h in handles {
            merged.merge(&h.join().unwrap());
        }
        assert_eq!(shared.count(), 40_000, "seed {seed}: lost records");
        assert_eq!(shared.snapshot(), merged, "seed {seed}: shared != merged");
    }
}

#[test]
fn prop_metrics_snapshot_deltas_nonnegative_and_additive() {
    use memtrade::metrics::Histogram;
    let mut rng = Rng::new(210);
    for case in 0..50 {
        let h = Histogram::new();
        let mut snaps = vec![h.snapshot()];
        for _ in 0..4 {
            for _ in 0..rng.below(500) {
                h.record(rng.below(1 << 30));
            }
            snaps.push(h.snapshot());
        }
        // Every window is non-negative, and windows sum to the total.
        let mut windows_total = 0u64;
        for w in snaps.windows(2) {
            let d = w[1].delta(&w[0]);
            assert!(d.counts.iter().all(|&c| c < 1 << 60), "case {case}: underflow");
            assert_eq!(d.count(), w[1].count() - w[0].count(), "case {case}");
            windows_total += d.count();
        }
        assert_eq!(windows_total, h.count(), "case {case}: windows don't tile");
    }
}

#[test]
fn prop_metrics_quantiles_monotone_and_in_range() {
    use memtrade::metrics::Histogram;
    let mut rng = Rng::new(211);
    for case in 0..100 {
        let h = Histogram::new();
        let n = 1 + rng.below(2_000);
        let (mut lo, mut hi) = (u64::MAX, 0u64);
        for _ in 0..n {
            // Mix scales so many octaves are hit.
            let v = rng.below(10u64.pow(1 + rng.below(9) as u32));
            lo = lo.min(v);
            hi = hi.max(v);
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), n, "case {case}");
        let mut prev = 0.0f64;
        for i in 0..=20 {
            let q = i as f64 / 20.0;
            let v = s.quantile(q);
            assert!(v >= prev, "case {case}: quantile({q}) = {v} < {prev}");
            prev = v;
        }
        // Bucketed estimates stay within one bucket of the extremes.
        assert!(s.quantile(0.0) <= (lo.max(1) * 2) as f64, "case {case}");
        assert!(s.quantile(1.0) <= (hi.max(1) as f64) * 2.0 + 1.0, "case {case}");
        assert!(s.p999() >= s.p99() && s.p99() >= s.p90() && s.p90() >= s.p50());
    }
}

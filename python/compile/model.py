"""L2 JAX model: the broker's two compute graphs (build-time only).

``forecast_model`` — the availability predictor (paper §5.1).  The paper
fits ARIMA(p, d, q=0) per producer with a daily hyperparameter grid search;
here the (d, p) selection happens *inside* the lowered graph every call:
both the raw (d=0) and first-differenced (d=1) series are fitted with the
L1 AR kernel, the candidate with the smaller one-step prediction-error
variance (in original units) wins per series, and its H-step forecast plus
a z·sigma·sqrt(h) safety margin produces the "safe available" memory the
broker may lease out.

``demand_model`` — the market-clearing evaluator for the pricing engine
(paper §5.3).  Given every consumer's extra-hit curve and per-hit value,
it evaluates the three candidate prices {p-dp, p, p+dp} via the L1 demand
kernel and reduces to total volume and producer revenue per candidate.

Both graphs are lowered once by aot.py to HLO text and executed from the
Rust broker via PJRT; python never runs at market time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.forecast import ar_forecast
from compile.kernels.demand import demand_scan

# Compiled-in shapes (the Rust runtime pads/chunks to these).
FORECAST_BATCH = 256
FORECAST_WINDOW = 288  # 24h at 5-minute samples
AR_ORDER = 4
HORIZON = 12           # predict 1h ahead at 5-minute resolution
SAFETY_Z = 1.64        # one-sided 95% margin

DEMAND_BATCH = 1024
DEMAND_SIZES = 64      # extra-slab curve resolution
N_PRICES = 3


def forecast_model(usage: jax.Array, capacity: jax.Array):
    """Availability predictor.

    Args:
      usage: `[B, W]` recent memory usage (GB) per producer.
      capacity: `[B]` producer VM memory capacity (GB).

    Returns:
      pred:  `[B, H]` predicted usage (GB), clipped to [0, capacity].
      safe:  `[B, H]` safe leasable memory (GB) after the sigma margin.
      sigma: `[B]`   selected model's one-step prediction-error std.
      used_d:`[B]`   1.0 where the differenced (d=1) model was selected.
    """
    usage = usage.astype(jnp.float32)
    b, w = usage.shape

    # Candidate d=0: AR(p) on the raw series.
    # Full-batch tile: grid=1 per pallas_call (measured ~25% faster under
    # the CPU PJRT interpret path; still VMEM-safe on TPU at 294 KB/block).
    f0, s0 = ar_forecast(usage, order=AR_ORDER, horizon=HORIZON, tile_b=FORECAST_BATCH)

    # Candidate d=1: AR(p) on first differences, forecasts re-integrated
    # from the last observed level.
    diff = usage[:, 1:] - usage[:, :-1]
    fd, s1 = ar_forecast(diff, order=AR_ORDER, horizon=HORIZON, tile_b=FORECAST_BATCH)
    last = usage[:, -1:]
    f1 = last + jnp.cumsum(fd, axis=1)

    # Model selection: both sigmas are one-step errors in GB (differencing
    # preserves units), pick the smaller per series.
    use_d1 = (s1 < s0)[:, None]
    pred = jnp.where(use_d1, f1, f0)
    sigma = jnp.where(use_d1[:, 0], s1, s0)

    cap = capacity.astype(jnp.float32)[:, None]
    pred = jnp.clip(pred, 0.0, cap)

    # Uncertainty grows ~sqrt(h) for a random-walk-ish error process.
    h = jnp.arange(1, HORIZON + 1, dtype=jnp.float32)[None, :]
    margin = SAFETY_Z * sigma[:, None] * jnp.sqrt(h)
    safe = jnp.clip(cap - (pred + margin), 0.0, cap)

    return pred, safe, sigma, use_d1[:, 0].astype(jnp.float32)


def demand_model(gain: jax.Array, hit_value: jax.Array, prices: jax.Array):
    """Market demand/revenue at candidate prices.

    Args:
      gain: `[B, S]` extra hits/sec gained by leasing s slabs.
      hit_value: `[B]` dollar value of one hit/sec over the lease.
      prices: `[K]` candidate $ per slab-hour.

    Returns:
      demand:  `[B, K]` slabs demanded per consumer per candidate.
      volume:  `[K]` total slabs demanded.
      revenue: `[K]` producer revenue = price * volume.
    """
    demand = demand_scan(gain, hit_value, prices, tile_b=DEMAND_BATCH)
    volume = jnp.sum(demand, axis=0)
    revenue = prices.astype(jnp.float32) * volume
    return demand, volume, revenue


def forecast_example_args():
    spec = jax.ShapeDtypeStruct((FORECAST_BATCH, FORECAST_WINDOW), jnp.float32)
    cap = jax.ShapeDtypeStruct((FORECAST_BATCH,), jnp.float32)
    return (spec, cap)


def demand_example_args():
    gain = jax.ShapeDtypeStruct((DEMAND_BATCH, DEMAND_SIZES), jnp.float32)
    val = jax.ShapeDtypeStruct((DEMAND_BATCH,), jnp.float32)
    prices = jax.ShapeDtypeStruct((N_PRICES,), jnp.float32)
    return (gain, val, prices)

"""L1 Pallas kernel: batched AR(p) fit + H-step forecast.

This is the numeric hot-spot of the Memtrade broker's availability
predictor (paper §5.1).  For a batch of producer memory-usage windows it

  1. mean-centers each series,
  2. computes autocovariances r_0..r_p as lag-shifted dot products
     (MXU/VPU-friendly dense reductions),
  3. fits AR(p) coefficients with an unrolled Levinson-Durbin recursion
     (p is a small compile-time constant, so the recursion is straight-line
     vector code over the batch lanes),
  4. iterates the AR recurrence H steps ahead,
  5. reports the one-step prediction-error variance from the recursion
     (used by L2 for model selection and the safety margin).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid tiles the batch
dimension; each program instance owns a `[TILE_B, W]` VMEM block.  There is
no data-dependent control flow or indexing, so the kernel lowers to plain
HLO under ``interpret=True`` and runs on the CPU PJRT client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Ridge term guarding r_0 for (near-)constant series.
RIDGE = 1e-6
# Reflection-coefficient clamp keeping the AR filter stable.
KAPPA_CLAMP = 0.999


def _ar_kernel(x_ref, fcast_ref, sigma_ref, *, order: int, horizon: int):
    """Kernel body: x_ref[TILE_B, W] -> fcast_ref[TILE_B, H], sigma_ref[TILE_B, 1]."""
    x = x_ref[...].astype(jnp.float32)
    tile_b, w = x.shape

    mu = jnp.mean(x, axis=1, keepdims=True)
    xc = x - mu

    # Autocovariances r_0..r_p: lag-shifted dot products, normalized by W so
    # all lags share a scale (biased estimator, standard for Yule-Walker).
    rs = []
    for lag in range(order + 1):
        if lag == 0:
            r = jnp.sum(xc * xc, axis=1)
        else:
            r = jnp.sum(xc[:, lag:] * xc[:, :-lag], axis=1)
        rs.append(r / jnp.float32(w))
    r0 = rs[0] + jnp.float32(RIDGE)

    # Levinson-Durbin, unrolled over the order. phi holds AR coefficients
    # phi_1..phi_k after step k; err is the prediction-error variance.
    phi = [jnp.zeros_like(r0) for _ in range(order)]
    err = r0
    for k in range(1, order + 1):
        acc = rs[k]
        for j in range(1, k):
            acc = acc - phi[j - 1] * rs[k - j]
        kappa = acc / err
        kappa = jnp.clip(kappa, -KAPPA_CLAMP, KAPPA_CLAMP)
        new_phi = list(phi)
        new_phi[k - 1] = kappa
        for j in range(1, k):
            new_phi[j - 1] = phi[j - 1] - kappa * phi[k - 1 - j]
        phi = new_phi
        err = err * (1.0 - kappa * kappa)

    # Iterated H-step forecast on the centered series. window[j] = x_{t-1-j}.
    window = [xc[:, w - 1 - j] for j in range(order)]
    outs = []
    for _h in range(horizon):
        f = jnp.zeros_like(r0)
        for j in range(order):
            f = f + phi[j] * window[j]
        outs.append(f)
        window = [f] + window[:-1]

    fcast = jnp.stack(outs, axis=1) + mu  # [TILE_B, H], un-centered
    fcast_ref[...] = fcast
    sigma_ref[...] = jnp.sqrt(jnp.maximum(err, 0.0))[:, None]


@functools.partial(jax.jit, static_argnames=("order", "horizon", "tile_b"))
def ar_forecast(x: jax.Array, *, order: int = 4, horizon: int = 12,
                tile_b: int = 128) -> tuple[jax.Array, jax.Array]:
    """Batched AR(p) forecast.

    Args:
      x: `[B, W]` float32 series (B must be a multiple of ``tile_b``;
         callers pad — see model.py).
      order: AR order p (compile-time).
      horizon: forecast steps H (compile-time).
      tile_b: batch tile per grid step; `[tile_b, W]` must fit in VMEM.

    Returns:
      (forecast `[B, H]`, sigma `[B]`) — sigma is the one-step
      prediction-error std-dev from the Levinson-Durbin recursion.
    """
    b, w = x.shape
    if b % tile_b != 0:
        raise ValueError(f"batch {b} not a multiple of tile {tile_b}")
    grid = (b // tile_b,)
    kernel = functools.partial(_ar_kernel, order=order, horizon=horizon)
    fcast, sigma = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_b, w), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tile_b, horizon), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, horizon), jnp.float32),
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x)
    return fcast, sigma[:, 0]

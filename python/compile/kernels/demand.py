"""L1 Pallas kernel: batched market-demand scan (paper §5.3, §6.2).

For the broker's price local-search, evaluate — for every consumer and every
candidate price — the surplus-maximizing number of extra remote-memory slabs
to lease.  Consumer i with expected extra-hit curve ``gain[i, s]`` (hits/sec
gained by leasing s slabs, s = 0..S-1) and per-hit value ``hit_value[i]``
has surplus

    surplus(i, s, k) = hit_value[i] * gain[i, s] - price[k] * s

and demands ``argmax_s surplus`` (0 if the max surplus is <= 0: consumers
only lease when remote memory is worth more than it costs — the paper's
consumer-surplus rule).

The scan over s is a dense vectorized max/argmax over a `[TILE_B, S]` VMEM
block — no data-dependent shapes, so it lowers to plain HLO under
``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _demand_kernel(gain_ref, value_ref, prices_ref, demand_ref, *, n_prices: int):
    gain = gain_ref[...].astype(jnp.float32)          # [TB, S]
    value = value_ref[...].astype(jnp.float32)        # [TB, 1]
    prices = prices_ref[...].astype(jnp.float32)      # [1, K]
    tile_b, s = gain.shape

    slabs = jnp.arange(s, dtype=jnp.float32)[None, :]  # [1, S]
    benefit = value * gain                             # [TB, S]
    outs = []
    for k in range(n_prices):
        surplus = benefit - prices[0, k] * slabs       # [TB, S]
        best = jnp.argmax(surplus, axis=1).astype(jnp.float32)
        best_val = jnp.max(surplus, axis=1)
        outs.append(jnp.where(best_val > 0.0, best, 0.0))
    demand_ref[...] = jnp.stack(outs, axis=1)          # [TB, K]


@functools.partial(jax.jit, static_argnames=("tile_b",))
def demand_scan(gain: jax.Array, hit_value: jax.Array, prices: jax.Array,
                *, tile_b: int = 256) -> jax.Array:
    """Per-consumer demanded slabs at each candidate price.

    Args:
      gain: `[B, S]` extra-hit curve per consumer (gain[:, 0] == 0).
      hit_value: `[B]` dollar value of one hit/sec for an hour lease.
      prices: `[K]` candidate prices ($ per slab-hour).
      tile_b: batch tile size; B must be a multiple.

    Returns:
      demand `[B, K]` float32 slab counts (integral values).
    """
    b, s = gain.shape
    (k,) = prices.shape
    if b % tile_b != 0:
        raise ValueError(f"batch {b} not a multiple of tile {tile_b}")
    grid = (b // tile_b,)
    kernel = functools.partial(_demand_kernel, n_prices=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, s), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=True,
    )(gain, hit_value[:, None], prices[None, :])

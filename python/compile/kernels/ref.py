"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: straightforward, unoptimized
implementations of the same math used by ``forecast.py`` and ``demand.py``.
pytest (and hypothesis) assert allclose between kernel and oracle over a
sweep of shapes and inputs.
"""

from __future__ import annotations

import jax.numpy as jnp

RIDGE = 1e-6
KAPPA_CLAMP = 0.999


def autocov(x, order):
    """Biased autocovariances r_0..r_order for centered series x [B, W]."""
    b, w = x.shape
    rs = []
    for lag in range(order + 1):
        if lag == 0:
            rs.append(jnp.sum(x * x, axis=1) / w)
        else:
            rs.append(jnp.sum(x[:, lag:] * x[:, :-lag], axis=1) / w)
    return rs


def levinson_durbin(rs, order):
    """Batched Levinson-Durbin. rs: list of [B] arrays, len order+1.

    Returns (phi list of [B] arrays len order, err [B]).
    """
    r0 = rs[0] + RIDGE
    phi = [jnp.zeros_like(r0) for _ in range(order)]
    err = r0
    for k in range(1, order + 1):
        acc = rs[k]
        for j in range(1, k):
            acc = acc - phi[j - 1] * rs[k - j]
        kappa = jnp.clip(acc / err, -KAPPA_CLAMP, KAPPA_CLAMP)
        new_phi = list(phi)
        new_phi[k - 1] = kappa
        for j in range(1, k):
            new_phi[j - 1] = phi[j - 1] - kappa * phi[k - 1 - j]
        phi = new_phi
        err = err * (1.0 - kappa * kappa)
    return phi, err


def ar_forecast_ref(x, order=4, horizon=12):
    """Oracle for kernels.forecast.ar_forecast. x: [B, W] float32."""
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=1, keepdims=True)
    xc = x - mu
    rs = autocov(xc, order)
    phi, err = levinson_durbin(rs, order)
    b, w = x.shape
    window = [xc[:, w - 1 - j] for j in range(order)]
    outs = []
    for _ in range(horizon):
        f = jnp.zeros_like(err)
        for j in range(order):
            f = f + phi[j] * window[j]
        outs.append(f)
        window = [f] + window[:-1]
    fcast = jnp.stack(outs, axis=1) + mu
    sigma = jnp.sqrt(jnp.maximum(err, 0.0))
    return fcast, sigma


def demand_ref(gain, hit_value, prices):
    """Oracle for kernels.demand.demand_scan.

    gain: [B, S], hit_value: [B], prices: [K]. Returns [B, K].
    """
    gain = gain.astype(jnp.float32)
    b, s = gain.shape
    slabs = jnp.arange(s, dtype=jnp.float32)[None, :]
    benefit = hit_value[:, None] * gain
    outs = []
    for k in range(prices.shape[0]):
        surplus = benefit - prices[k] * slabs
        best = jnp.argmax(surplus, axis=1).astype(jnp.float32)
        best_val = jnp.max(surplus, axis=1)
        outs.append(jnp.where(best_val > 0.0, best, 0.0))
    return jnp.stack(outs, axis=1)

"""AOT export: lower the L2 graphs to HLO *text* for the Rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Emits:  forecast.hlo.txt, demand.hlo.txt, and a manifest with the
        compiled-in shapes the Rust runtime must honor.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)

    lowered_f = jax.jit(model.forecast_model).lower(*model.forecast_example_args())
    path_f = os.path.join(out_dir, "forecast.hlo.txt")
    with open(path_f, "w") as f:
        f.write(to_hlo_text(lowered_f))
    print(f"wrote {path_f}")

    lowered_d = jax.jit(model.demand_model).lower(*model.demand_example_args())
    path_d = os.path.join(out_dir, "demand.hlo.txt")
    with open(path_d, "w") as f:
        f.write(to_hlo_text(lowered_d))
    print(f"wrote {path_d}")

    manifest = {
        "forecast": {
            "batch": model.FORECAST_BATCH,
            "window": model.FORECAST_WINDOW,
            "order": model.AR_ORDER,
            "horizon": model.HORIZON,
            "safety_z": model.SAFETY_Z,
            "inputs": [["usage", [model.FORECAST_BATCH, model.FORECAST_WINDOW]],
                       ["capacity", [model.FORECAST_BATCH]]],
            "outputs": ["pred[B,H]", "safe[B,H]", "sigma[B]", "used_d[B]"],
        },
        "demand": {
            "batch": model.DEMAND_BATCH,
            "sizes": model.DEMAND_SIZES,
            "n_prices": model.N_PRICES,
            "inputs": [["gain", [model.DEMAND_BATCH, model.DEMAND_SIZES]],
                       ["hit_value", [model.DEMAND_BATCH]],
                       ["prices", [model.N_PRICES]]],
            "outputs": ["demand[B,K]", "volume[K]", "revenue[K]"],
        },
    }
    path_m = os.path.join(out_dir, "manifest.json")
    with open(path_m, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {path_m}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    # kept for Makefile compatibility with single-file invocations
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    export(out_dir or ".")


if __name__ == "__main__":
    main()

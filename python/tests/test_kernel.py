"""L1 correctness: Pallas kernels vs pure-jnp oracles.

The core signal: if these pass, the HLO the Rust broker executes computes
exactly what ref.py says it should.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.forecast import ar_forecast
from compile.kernels.demand import demand_scan
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- forecast

def _series(b, w, seed=0, kind="ar"):
    r = _rng(seed)
    if kind == "ar":
        # Stable AR(2) + noise, per batch row.
        x = np.zeros((b, w), dtype=np.float32)
        phi1 = r.uniform(0.2, 0.7, size=b)
        phi2 = r.uniform(-0.3, 0.2, size=b)
        noise = r.normal(0, 1, size=(b, w)).astype(np.float32)
        for t in range(2, w):
            x[:, t] = phi1 * x[:, t - 1] + phi2 * x[:, t - 2] + noise[:, t]
        return x + 10.0
    if kind == "diurnal":
        t = np.arange(w, dtype=np.float32)
        base = 20 + 8 * np.sin(2 * np.pi * t / 288.0)[None, :]
        return (base + r.normal(0, 0.5, size=(b, w))).astype(np.float32)
    if kind == "constant":
        return np.full((b, w), 7.5, dtype=np.float32)
    if kind == "linear":
        t = np.arange(w, dtype=np.float32)[None, :]
        return np.repeat(0.05 * t + 3.0, b, axis=0).astype(np.float32)
    raise ValueError(kind)


@pytest.mark.parametrize("kind", ["ar", "diurnal", "constant", "linear"])
@pytest.mark.parametrize("b,w,tile", [(128, 288, 128), (256, 288, 128), (64, 96, 64)])
def test_forecast_matches_ref(kind, b, w, tile):
    x = jnp.asarray(_series(b, w, seed=hash((kind, b, w)) % 2**31, kind=kind))
    f_k, s_k = ar_forecast(x, order=4, horizon=12, tile_b=tile)
    f_r, s_r = ref.ar_forecast_ref(x, order=4, horizon=12)
    np.testing.assert_allclose(f_k, f_r, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(s_k, s_r, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("order", [1, 2, 4, 8])
def test_forecast_orders(order):
    x = jnp.asarray(_series(64, 128, seed=order, kind="ar"))
    f_k, s_k = ar_forecast(x, order=order, horizon=6, tile_b=64)
    f_r, s_r = ref.ar_forecast_ref(x, order=order, horizon=6)
    np.testing.assert_allclose(f_k, f_r, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(s_k, s_r, rtol=1e-4, atol=1e-4)


def test_forecast_constant_series_is_flat():
    x = jnp.full((64, 96), 5.0, dtype=jnp.float32)
    f, s = ar_forecast(x, order=4, horizon=8, tile_b=64)
    np.testing.assert_allclose(f, 5.0, atol=1e-3)
    assert float(jnp.max(s)) < 1e-2


def test_forecast_tracks_strong_ar1():
    # phi ~ 0.95 AR(1): one-step forecast should be close to phi * last.
    r = _rng(42)
    b, w = 64, 256
    x = np.zeros((b, w), dtype=np.float32)
    eps = r.normal(0, 0.1, size=(b, w)).astype(np.float32)
    for t in range(1, w):
        x[:, t] = 0.95 * x[:, t - 1] + eps[:, t]
    xj = jnp.asarray(x)
    f, _ = ar_forecast(xj, order=4, horizon=1, tile_b=64)
    mu = x.mean(axis=1)
    expected = mu + 0.95 * (x[:, -1] - mu)
    np.testing.assert_allclose(f[:, 0], expected, atol=0.15)


@settings(max_examples=25, deadline=None)
@given(
    b_tiles=st.integers(1, 3),
    tile=st.sampled_from([32, 64]),
    w=st.integers(24, 160),
    order=st.integers(1, 6),
    horizon=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-3, 1e3),
)
def test_forecast_hypothesis(b_tiles, tile, w, order, horizon, seed, scale):
    b = b_tiles * tile
    r = _rng(seed)
    x = jnp.asarray((r.normal(0, 1, size=(b, w)) * scale).astype(np.float32))
    f_k, s_k = ar_forecast(x, order=order, horizon=horizon, tile_b=tile)
    f_r, s_r = ref.ar_forecast_ref(x, order=order, horizon=horizon)
    np.testing.assert_allclose(f_k, f_r, rtol=1e-3, atol=1e-3 * scale)
    np.testing.assert_allclose(s_k, s_r, rtol=1e-3, atol=1e-4 * scale)


def test_forecast_rejects_bad_tile():
    x = jnp.zeros((100, 32), dtype=jnp.float32)
    with pytest.raises(ValueError):
        ar_forecast(x, tile_b=64)


# ------------------------------------------------------------------ demand

def _mrc_gain(b, s, seed=0):
    """Concave, increasing extra-hit curves (like real MRC-derived gains)."""
    r = _rng(seed)
    rate = r.uniform(10, 5000, size=(b, 1))
    knee = r.uniform(2, s, size=(b, 1))
    sizes = np.arange(s, dtype=np.float32)[None, :]
    gain = rate * (1.0 - np.exp(-sizes / knee))
    return gain.astype(np.float32)


@pytest.mark.parametrize("b,s,tile", [(256, 64, 256), (1024, 64, 256), (512, 32, 128)])
def test_demand_matches_ref(b, s, tile):
    gain = jnp.asarray(_mrc_gain(b, s, seed=b + s))
    value = jnp.asarray(_rng(b).uniform(1e-6, 1e-3, size=b).astype(np.float32))
    prices = jnp.asarray(np.array([0.001, 0.003, 0.01], dtype=np.float32))
    d_k = demand_scan(gain, value, prices, tile_b=tile)
    d_r = ref.demand_ref(gain, value, prices)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))


def test_demand_zero_price_takes_max_gain():
    # Strictly increasing gain (saturating exponentials plateau in f32 and
    # make argmax ambiguous), so zero price must demand the full curve.
    sizes = np.arange(64, dtype=np.float32)[None, :]
    gain = jnp.asarray(np.repeat(sizes, 128, axis=0))
    value = jnp.full((128,), 1e-3, dtype=jnp.float32)
    prices = jnp.asarray(np.array([0.0], dtype=np.float32))
    d = demand_scan(gain, value, prices, tile_b=128)
    assert int(jnp.min(d)) == 63


def test_demand_huge_price_is_zero():
    gain = jnp.asarray(_mrc_gain(128, 64, seed=10))
    value = jnp.full((128,), 1e-6, dtype=jnp.float32)
    prices = jnp.asarray(np.array([1e9], dtype=np.float32))
    d = demand_scan(gain, value, prices, tile_b=128)
    assert float(jnp.max(d)) == 0.0


def test_demand_monotone_in_price():
    gain = jnp.asarray(_mrc_gain(256, 64, seed=11))
    value = jnp.asarray(_rng(3).uniform(1e-6, 1e-3, size=256).astype(np.float32))
    prices = jnp.asarray(np.array([0.0005, 0.002, 0.02], dtype=np.float32))
    d = np.asarray(demand_scan(gain, value, prices, tile_b=256))
    # Higher price => weakly less demand (gain curves are concave increasing).
    assert np.all(d[:, 0] >= d[:, 1])
    assert np.all(d[:, 1] >= d[:, 2])


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(1, 3),
    tile=st.sampled_from([64, 128]),
    s=st.integers(4, 96),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_demand_hypothesis(tiles, tile, s, k, seed):
    b = tiles * tile
    r = _rng(seed)
    gain = jnp.asarray(r.uniform(0, 1000, size=(b, s)).astype(np.float32))
    value = jnp.asarray(r.uniform(0, 1e-2, size=b).astype(np.float32))
    prices = jnp.asarray(r.uniform(0, 0.05, size=k).astype(np.float32))
    d_k = demand_scan(gain, value, prices, tile_b=tile)
    d_r = ref.demand_ref(gain, value, prices)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))

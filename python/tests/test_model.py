"""L2 model semantics: shapes, clipping, model selection, market reduction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _usage(b=model.FORECAST_BATCH, w=model.FORECAST_WINDOW, seed=0):
    r = np.random.default_rng(seed)
    t = np.arange(w, dtype=np.float32)
    base = 12 + 6 * np.sin(2 * np.pi * t / 288.0)[None, :]
    x = base + r.normal(0, 0.4, size=(b, w))
    return jnp.asarray(x.astype(np.float32))


def test_forecast_model_shapes():
    usage = _usage()
    cap = jnp.full((model.FORECAST_BATCH,), 32.0, dtype=jnp.float32)
    pred, safe, sigma, used_d = model.forecast_model(usage, cap)
    assert pred.shape == (model.FORECAST_BATCH, model.HORIZON)
    assert safe.shape == (model.FORECAST_BATCH, model.HORIZON)
    assert sigma.shape == (model.FORECAST_BATCH,)
    assert used_d.shape == (model.FORECAST_BATCH,)


def test_forecast_model_bounds():
    usage = _usage(seed=1)
    cap = jnp.full((model.FORECAST_BATCH,), 32.0, dtype=jnp.float32)
    pred, safe, sigma, used_d = model.forecast_model(usage, cap)
    assert float(jnp.min(pred)) >= 0.0
    assert float(jnp.max(pred)) <= 32.0
    assert float(jnp.min(safe)) >= 0.0
    assert float(jnp.max(safe)) <= 32.0
    # safe + pred + margin <= cap  =>  safe <= cap - pred (margin >= 0)
    assert float(jnp.max(safe + pred - 32.0)) <= 1e-3
    assert set(np.unique(np.asarray(used_d))) <= {0.0, 1.0}


def test_forecast_model_safe_shrinks_with_horizon():
    usage = _usage(seed=2)
    cap = jnp.full((model.FORECAST_BATCH,), 64.0, dtype=jnp.float32)
    pred, safe, sigma, _ = model.forecast_model(usage, cap)
    # For a stationary series the sqrt(h) margin means safe is (weakly)
    # decreasing in h wherever pred is flat; check the aggregate trend.
    first = float(jnp.mean(safe[:, 0]))
    last = float(jnp.mean(safe[:, -1]))
    assert last <= first + 1e-3


def test_forecast_model_prefers_diff_for_trend():
    # A strong linear ramp is far better fit by the d=1 model.
    b, w = model.FORECAST_BATCH, model.FORECAST_WINDOW
    t = np.arange(w, dtype=np.float32)[None, :]
    r = np.random.default_rng(3)
    x = jnp.asarray((0.1 * t + r.normal(0, 0.01, size=(b, w))).astype(np.float32))
    cap = jnp.full((b,), 1e6, dtype=jnp.float32)
    pred, safe, sigma, used_d = model.forecast_model(x, cap)
    assert float(jnp.mean(used_d)) > 0.9
    # And the forecast should continue the ramp.
    expected = 0.1 * (w - 1) + 0.1 * np.arange(1, model.HORIZON + 1)
    np.testing.assert_allclose(np.asarray(pred[0]), expected, atol=0.5)


def test_demand_model_reduction():
    b, s, k = model.DEMAND_BATCH, model.DEMAND_SIZES, model.N_PRICES
    r = np.random.default_rng(7)
    gain = jnp.asarray(r.uniform(0, 100, size=(b, s)).astype(np.float32))
    value = jnp.asarray(r.uniform(0, 1e-3, size=b).astype(np.float32))
    prices = jnp.asarray(np.array([0.001, 0.002, 0.004], dtype=np.float32))
    demand, volume, revenue = model.demand_model(gain, value, prices)
    assert demand.shape == (b, k)
    d_r = ref.demand_ref(gain, value, prices)
    np.testing.assert_array_equal(np.asarray(demand), np.asarray(d_r))
    np.testing.assert_allclose(np.asarray(volume), np.asarray(demand).sum(axis=0), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(revenue), np.asarray(prices) * np.asarray(volume), rtol=1e-6)


def test_models_lower_to_hlo_text():
    """The AOT path itself: both graphs must lower to HLO text cleanly."""
    from compile import aot
    lowered = jax.jit(model.forecast_model).lower(*model.forecast_example_args())
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "f32[256,288]" in text
    lowered = jax.jit(model.demand_model).lower(*model.demand_example_args())
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "f32[1024,64]" in text

//! Security tour — paper §6.1: demonstrates confidentiality, integrity,
//! key substitution, and tamper detection against a *malicious producer*,
//! plus the §7.3 metadata-overhead accounting, all on the real
//! from-scratch AES-128-CBC + SHA-256.
//!
//! Run: `cargo run --release --example secure_kv_tour`

use memtrade::consumer::client::SecureKv;
use memtrade::kv::KvStore;
use memtrade::net::wire::{Request, Response};

/// A producer store that can be switched into malicious modes.
struct EvilProducer {
    store: KvStore,
    corrupt_values: bool,
    replay_other: bool,
}

impl EvilProducer {
    fn serve(&mut self, req: Request) -> Response {
        match req {
            Request::Get { key } => match self.store.get(&key).map(<[u8]>::to_vec) {
                Some(mut v) => {
                    if self.corrupt_values {
                        let n = v.len();
                        v[n / 2] ^= 0x80; // flip one bit
                    }
                    if self.replay_other {
                        if let Some(other) = self.store.sample_key() {
                            if other.as_ref() != key.as_slice() {
                                return Response::Value(
                                    self.store.get(&other).unwrap().to_vec(),
                                );
                            }
                        }
                    }
                    Response::Value(v)
                }
                None => Response::NotFound,
            },
            Request::Put { key, value } => {
                if self.store.put(&key, &value) {
                    Response::Stored
                } else {
                    Response::Rejected
                }
            }
            Request::Delete { key } => Response::Deleted(self.store.delete(&key)),
            Request::Ping => Response::Pong,
        }
    }
}

fn main() {
    println!("== Memtrade secure KV tour (paper §6.1) ==\n");
    let mut producer = EvilProducer {
        store: KvStore::new(16 << 20, 3),
        corrupt_values: false,
        replay_other: false,
    };
    let mut consumer = SecureKv::new(Some([0x42; 16]), true, 1);

    // 1. Confidentiality: the producer never sees keys or plaintext.
    println!("1. PUT 'ssn' -> '123-45-6789' through the envelope");
    {
        let mut t = |_p: u32, req: Request| producer.serve(req);
        assert!(consumer.put(&mut t, b"ssn", b"123-45-6789"));
    }
    let visible_key = producer.store.sample_key().unwrap();
    let visible_val = producer.store.get(&visible_key).unwrap().to_vec();
    println!("   producer sees key bytes: {visible_key:?} (a 64-bit counter, not 'ssn')");
    println!(
        "   producer sees value: {} bytes of ciphertext (IV || AES-CBC), plaintext absent: {}",
        visible_val.len(),
        !visible_val.windows(11).any(|w| w == b"123-45-6789")
    );

    // 2. Round trip.
    {
        let mut t = |_p: u32, req: Request| producer.serve(req);
        let v = consumer.get(&mut t, b"ssn").unwrap();
        assert_eq!(v, b"123-45-6789");
    }
    println!("2. GET verifies SHA-256 then decrypts: OK");

    // 3. Corruption detection.
    producer.corrupt_values = true;
    {
        let mut t = |_p: u32, req: Request| producer.serve(req);
        assert!(consumer.put(&mut t, b"acct", b"balance=1000"));
        let got = consumer.get(&mut t, b"acct");
        assert!(got.is_none());
    }
    println!(
        "3. producer flips one bit -> integrity check discards the value (failures: {})",
        consumer.stats.integrity_failures
    );
    producer.corrupt_values = false;

    // 4. Replay/substitution detection: returning a *different* valid
    //    entry still fails, because H binds the value to this key's
    //    metadata.
    producer.replay_other = true;
    {
        let mut t = |_p: u32, req: Request| producer.serve(req);
        assert!(consumer.put(&mut t, b"a", b"value-A"));
        assert!(consumer.put(&mut t, b"b", b"value-B"));
        let got = consumer.get(&mut t, b"a");
        assert!(got.is_none() || got.as_deref() == Some(b"value-A".as_ref()));
    }
    println!("4. producer substitutes another stored value -> rejected by hash binding");
    producer.replay_other = false;

    // 5. Metadata overhead (paper: 24 B/KV encrypted, 16 B integrity-only).
    println!(
        "5. local metadata: {} entries, {} bytes total",
        consumer.len(),
        consumer.metadata_bytes()
    );
    let mut int_only = SecureKv::new(None, true, 1);
    {
        let mut t = |_p: u32, req: Request| producer.serve(req);
        int_only.put(&mut t, b"public-data", b"not sensitive");
    }
    println!(
        "   integrity-only mode: {} bytes/entry (vs 24+key encrypted)",
        int_only.metadata_bytes() - b"public-data".len()
    );

    println!("\nsecure_kv_tour OK");
}

//! Quickstart: the whole Memtrade flow in one process, over a real TCP
//! producer store.
//!
//! 1. A producer VM (simulated guest app + harvester) harvests idle
//!    memory and exposes a producer store on localhost.
//! 2. A broker (with the AOT forecast artifact, if built) predicts the
//!    producer's availability and grants a lease.
//! 3. A consumer connects with the secure KV client (real AES-128-CBC +
//!    SHA-256) and serves YCSB traffic against the leased memory.
//!
//! Run: `cargo run --release --example quickstart`

use memtrade::broker::placement::ConsumerRequest;
use memtrade::broker::predictor::AvailabilityPredictor;
use memtrade::broker::pricing::{PricingEngine, PricingStrategy};
use memtrade::broker::Broker;
use memtrade::consumer::client::SecureKv;
use memtrade::core::config::{BrokerConfig, HarvesterConfig};
use memtrade::core::{ConsumerId, Money, ProducerId, SimTime, GIB};
use memtrade::mem::SwapDevice;
use memtrade::net::tcp::{KvClient, ProducerStoreServer};
use memtrade::net::wire::{Request, Response};
use memtrade::producer::Producer;
use memtrade::util::rng::Rng;
use memtrade::util::stats::LatencyRecorder;
use memtrade::workload::apps::{AppKind, AppModel, AppRunner};
use memtrade::workload::ycsb::{Op, YcsbWorkload};

fn main() {
    println!("== Memtrade quickstart ==\n");

    // ---- 1. Producer: harvest idle memory from a Redis-like guest.
    let app = AppRunner::new(
        AppModel::preset(AppKind::Redis),
        4 << 20,
        SwapDevice::Ssd,
        Some(SimTime::from_mins(5)),
        7,
    );
    let mut producer =
        Producer::new(ProducerId(1), app, HarvesterConfig::default(), 64 << 20);
    println!("producer: Redis guest on an 8 GB VM; harvesting for 30 simulated minutes...");
    let epoch = SimTime::from_secs(5);
    for e in 1..=360u64 {
        producer.tick(SimTime::from_micros(e * epoch.as_micros()), epoch);
    }
    let shape = producer.app.memory.shape();
    println!(
        "  harvestable: {:.2} GB (RSS {:.2} GB, Silo {:.2} GB, swapped {:.2} GB)\n",
        shape.harvestable as f64 / GIB as f64,
        shape.rss as f64 / GIB as f64,
        shape.silo as f64 / GIB as f64,
        shape.swapped as f64 / GIB as f64,
    );

    // ---- 2. Broker: register, predict availability, grant a lease.
    let predictor = AvailabilityPredictor::auto();
    println!(
        "broker: availability predictor backend = {}",
        if predictor.is_pjrt() { "PJRT (AOT artifacts)" } else { "pure-Rust fallback" }
    );
    let pricing = PricingEngine::new(
        PricingStrategy::FixedFraction,
        Money::from_dollars(0.00001),
        0.00002,
    );
    let mut broker = Broker::new(BrokerConfig::default(), predictor, pricing);
    broker.registry.register_producer(ProducerId(1), 8.0);
    let rss_gb = shape.rss as f32 / GIB as f32;
    for t in 0..288u64 {
        broker
            .registry
            .report_usage(ProducerId(1), SimTime::from_secs(t * 300), rss_gb);
    }
    broker.registry.update_producer_resources(
        ProducerId(1),
        producer.manager.free_slabs(),
        0.9,
        0.9,
    );
    broker.predictor.refresh(&mut broker.registry, SimTime::from_hours(24));
    broker.pricing.adjust(&broker.registry, Money::from_dollars(0.0026), 64 << 20);
    broker.registry.register_consumer(ConsumerId(100));

    let request = ConsumerRequest {
        consumer: ConsumerId(100),
        slabs: 16, // 1 GB
        min_slabs: 4,
        lease: SimTime::from_hours(1),
        max_price_per_slab_hour: None,
        latency_us_to: Default::default(),
        weights: None,
    };
    let leases = broker.request_memory(SimTime::from_hours(24), request);
    assert!(!leases.is_empty(), "broker found no capacity");
    let lease = leases[0].clone();
    println!(
        "  lease granted: {} slabs ({} MB) at {}/slab·h (total {})\n",
        lease.slabs,
        lease.bytes() >> 20,
        lease.price_per_slab_hour,
        lease.total_cost(),
    );

    // ---- 3. Producer store over real TCP + secure consumer client.
    let server = ProducerStoreServer::start(
        "127.0.0.1:0",
        lease.bytes() as usize,
        Some(125_000_000),
        3,
    )
    .expect("bind producer store");
    println!("producer store: listening on {}", server.addr());

    let mut tcp = KvClient::connect(server.addr()).expect("connect");
    let mut transport = |_p: u32, req: Request| -> Response {
        tcp.call(&req).unwrap_or(Response::Error("io".into()))
    };
    let mut secure = SecureKv::new(Some([42u8; 16]), true, 1);

    let workload = YcsbWorkload::paper_default(20_000, 1024);
    let mut rng = Rng::new(11);
    let mut rec = LatencyRecorder::new();
    let n_ops = 20_000u64;
    let started = std::time::Instant::now();
    for _ in 0..n_ops {
        let op = workload.next_op(&mut rng);
        let key = YcsbWorkload::key_bytes(op.key());
        let t0 = std::time::Instant::now();
        match op {
            Op::Read { .. } => {
                if secure.get(&mut transport, &key).is_none() {
                    let value = vec![0xAB; 1024];
                    let _ = secure.put(&mut transport, &key, &value);
                }
            }
            Op::Update { .. } => {
                let value = vec![0xCD; 1024];
                let _ = secure.put(&mut transport, &key, &value);
            }
        }
        rec.record(t0.elapsed().as_micros() as f64);
    }
    let dt = started.elapsed().as_secs_f64();
    println!(
        "consumer: {} secure YCSB ops in {:.2}s ({:.0} ops/s)",
        n_ops,
        dt,
        n_ops as f64 / dt
    );
    println!(
        "  latency avg {:.1}µs p50 {:.1}µs p99 {:.1}µs | remote hit ratio {:.3}",
        rec.mean(),
        rec.p50(),
        rec.p99(),
        secure.hit_ratio()
    );
    println!(
        "  integrity failures: {} | local metadata: {} KB",
        secure.stats.integrity_failures,
        secure.metadata_bytes() / 1024
    );
    let stats = server.stats();
    println!(
        "producer store: {} puts, {} hits, {} misses, {} evictions",
        stats.puts, stats.hits, stats.misses, stats.evictions
    );
    server.stop();

    // ---- 4. Purchasing strategy (§6.2): profile the workload's MRC with
    // SHARDS sampling and size the next lease against the market price.
    println!("\npurchasing strategy (§6.2):");
    let mut profiler = memtrade::consumer::mrc::MrcProfiler::new(0.2, 500, 64);
    let mut rng2 = Rng::new(77);
    for _ in 0..200_000 {
        let op = workload.next_op(&mut rng2);
        profiler.record(&YcsbWorkload::key_bytes(op.key()));
    }
    let mrc_points = profiler.mrc();
    // Convert the key-granular MRC into the byte-granular curve the
    // purchase planner consumes (~1.1 KB/KV incl. overheads).
    let bytes_per_key = 1024 + 80;
    let mrc = memtrade::workload::memcachier::Mrc {
        app_id: 0,
        miss_ratio: mrc_points.clone(),
        granularity_bytes: 500 * bytes_per_key,
        req_rate: 20_000.0,
    };
    let hit_value = memtrade::consumer::purchase::price_per_hit_hour(
        Money::from_dollars(0.096), // T2.xLarge-ish VM cost
        15_000.0,
    );
    let plan = memtrade::consumer::purchase::plan(
        &mrc,
        8 << 20, // current local cache
        64 << 20,
        64,
        hit_value,
        broker.current_price(),
        0.05, // assume 5% revocation risk
    );
    println!(
        "  SHARDS profile: {:.1}% of accesses sampled, mr(0)={:.2}, mr(16MB)={:.2}",
        profiler.sampled_fraction() * 100.0,
        mrc_points[0],
        mrc.at_bytes(16 << 20),
    );
    println!(
        "  plan at {}/slab·h: lease {} slabs (+{:.0} hits/s, surplus ${:.6}/h)",
        broker.current_price(),
        plan.slabs,
        plan.extra_hits_per_sec,
        plan.surplus_per_hour,
    );
    println!("\nquickstart OK");
}

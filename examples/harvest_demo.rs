//! Harvester walkthrough — paper §4/§7.1 (Fig 6/7/8 mechanics) on one
//! producer VM: watch the control loop harvest, absorb a workload burst
//! with Silo prefetch, and recover.
//!
//! Run: `cargo run --release --example harvest_demo`

use memtrade::core::config::HarvesterConfig;
use memtrade::core::{ProducerId, SimTime, GIB};
use memtrade::mem::SwapDevice;
use memtrade::producer::{HarvesterMode, Producer};
use memtrade::workload::apps::{AppKind, AppModel, AppRunner};

fn main() {
    println!("== Memtrade harvester demo: Redis on an 8 GB VM ==\n");
    let app = AppRunner::new(
        AppModel::preset(AppKind::Redis),
        4 << 20,
        SwapDevice::Ssd,
        Some(SimTime::from_mins(5)),
        5,
    );
    let baseline = app.baseline_latency_us();
    let mut p = Producer::new(ProducerId(1), app, HarvesterConfig::default(), 64 << 20);

    let epoch = SimTime::from_secs(5);
    println!("phase 1: steady workload, harvesting (40 min)...");
    println!(
        "{:>6} {:>10} {:>10} {:>10} {:>10} {:>12}",
        "t(min)", "RSS", "Silo", "disk", "free", "latency"
    );
    let mut e = 0u64;
    let show = |p: &Producer, e: u64, lat: f64| {
        if e % 60 == 0 {
            let s = p.app.memory.shape();
            println!(
                "{:>6} {:>9.2}G {:>9.2}G {:>9.2}G {:>9.2}G {:>10.0}µs",
                e * 5 / 60,
                s.rss as f64 / GIB as f64,
                s.silo as f64 / GIB as f64,
                s.swapped as f64 / GIB as f64,
                s.harvestable as f64 / GIB as f64,
                lat,
            );
        }
    };
    for _ in 0..480 {
        e += 1;
        let lat = p.tick(SimTime::from_micros(e * epoch.as_micros()), epoch);
        show(&p, e, lat);
    }
    let s = p.app.memory.shape();
    println!(
        "\nharvested {:.2} GB with latency {:.0}µs (baseline {:.0}µs)\n",
        s.harvestable as f64 / GIB as f64,
        p.tick(SimTime::from_micros((e + 1) * epoch.as_micros()), epoch),
        baseline
    );

    println!("phase 2: workload burst (Zipf -> uniform)...");
    p.app.set_distribution_uniform();
    let mut worst: f64 = 0.0;
    let mut recovered_at = None;
    for i in 0..240u64 {
        e += 1;
        let lat = p.tick(SimTime::from_micros(e * epoch.as_micros()), epoch);
        worst = worst.max(lat);
        if recovered_at.is_none() && lat < baseline * 1.1 && i > 2 {
            recovered_at = Some(i * 5);
        }
        show(&p, e, lat);
    }
    println!(
        "  burst peak latency {:.0}µs; recovered (within 10% of baseline) after {}s",
        worst,
        recovered_at.map(|s| s.to_string()).unwrap_or_else(|| ">1200".into())
    );
    println!(
        "  harvester mode now: {:?}; mode changes: {}; prefetched {} pages",
        match p.harvester.mode() {
            HarvesterMode::Harvesting => "harvesting",
            HarvesterMode::Recovery { .. } => "recovery",
        },
        p.harvester.mode_changes,
        p.app.memory.stats.prefetched,
    );

    println!("\nphase 3: burst ends; harvesting resumes (20 min)...");
    p.app.end_burst();
    for _ in 0..240 {
        e += 1;
        let lat = p.tick(SimTime::from_micros(e * epoch.as_micros()), epoch);
        show(&p, e, lat);
    }
    let s = p.app.memory.shape();
    println!(
        "\nfinal: {:.2} GB harvestable, Silo stats: {} admitted / {} mapped back / {} cooled",
        s.harvestable as f64 / GIB as f64,
        p.app.memory.stats.silo_hits,
        p.app.memory.stats.silo_hits,
        p.app.memory.stats.swap_outs,
    );
    println!("harvest_demo OK");
}

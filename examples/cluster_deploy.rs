//! End-to-end cluster deployment — the paper's §7.3 "Cluster Deployment"
//! (Table 2), and the repo's **end-to-end validation driver**: 110 VMs
//! (64 producers running the six paper workloads under harvesters, 46
//! consumers running YCSB at 10/30/50% remote), the broker predicting
//! availability with the AOT PJRT artifacts when built, real AES/SHA on
//! every remote op, and the full lease lifecycle.
//!
//! Run: `cargo run --release --example cluster_deploy [-- --quick]`
//! Results are recorded in EXPERIMENTS.md §Table 2.

use memtrade::core::SimTime;
use memtrade::util::fmt::{ms, pct, Table};
use memtrade::sim::cluster::{ClusterSim, ClusterSimConfig, ConsumerMode};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (n_producers, n_consumers, minutes) = if quick { (16, 12, 4) } else { (64, 46, 20) };
    println!(
        "== Memtrade cluster deployment: {n_producers} producers + {n_consumers} consumers, \
         {minutes} simulated minutes =="
    );

    let mut table = Table::new(vec![
        "consumers",
        "remote %",
        "avg w/o Memtrade",
        "avg w/ Memtrade",
        "improvement",
        "p99 w/o",
        "p99 w/",
    ]);

    let mut producer_table: Option<Table> = None;

    for remote in [0.10, 0.30, 0.50] {
        let run = |mode: ConsumerMode| -> ClusterSim {
            let cfg = ClusterSimConfig {
                n_producers,
                n_consumers,
                remote_fraction: remote,
                mode,
                n_keys: if quick { 4_000 } else { 20_000 },
                value_size: 1024,
                ops_per_epoch: if quick { 80 } else { 200 },
                page_bytes: if quick { 32 << 20 } else { 8 << 20 },
                seed: 99,
                harvest: true,
                use_pjrt: true,
            };
            let mut sim = ClusterSim::new(cfg);
            sim.bootstrap();
            sim.run(SimTime::from_mins(minutes));
            sim
        };
        let with = run(ConsumerMode::Secure);
        let without = run(ConsumerMode::NoMemtrade);
        table.row(vec![
            format!("{n_consumers} x YCSB/Redis"),
            pct(remote),
            ms(without.consumer_mean_latency()),
            ms(with.consumer_mean_latency()),
            format!(
                "{:.1}x",
                without.consumer_mean_latency() / with.consumer_mean_latency().max(1.0)
            ),
            ms(without.consumer_p99_latency()),
            ms(with.consumer_p99_latency()),
        ]);

        // Producer-side impact, measured once (harvester always on).
        if producer_table.is_none() {
            let mut pt = Table::new(vec!["producer app", "baseline", "under harvest", "impact"]);
            let mut by_kind: std::collections::BTreeMap<&str, (f64, f64, u32)> =
                Default::default();
            for p in &with.producers {
                let entry = by_kind
                    .entry(p.app.model.kind.name())
                    .or_insert((p.app.model.base_latency_us, 0.0, 0));
                entry.2 += 1;
            }
            // Re-measure steady-state producer latency from the sim run.
            for p in &with.producers {
                let kind = p.app.model.kind.name();
                let e = by_kind.get_mut(kind).unwrap();
                // The app's last-epoch mean comes from re-running an epoch.
                e.1 += p.app.model.base_latency_us; // placeholder; refined below
            }
            for (kind, (base, _sum, _n)) in &by_kind {
                // Measure impact precisely: one dedicated producer run.
                use memtrade::core::config::HarvesterConfig;
                use memtrade::core::ProducerId;
                use memtrade::mem::SwapDevice;
                use memtrade::producer::Producer;
                use memtrade::workload::apps::{AppKind, AppModel, AppRunner};
                let k = AppKind::ALL
                    .iter()
                    .find(|k| k.name() == *kind)
                    .copied()
                    .unwrap();
                let app = AppRunner::new(
                    AppModel::preset(k),
                    if quick { 32 << 20 } else { 8 << 20 },
                    SwapDevice::Ssd,
                    Some(SimTime::from_mins(5)),
                    3,
                );
                let mut p =
                    Producer::new(ProducerId(1), app, HarvesterConfig::default(), 64 << 20);
                let epoch = SimTime::from_secs(5);
                let epochs: u64 = if quick { 240 } else { 720 };
                let mut sum = 0.0;
                let mut n = 0u64;
                for e in 1..=epochs {
                    let lat = p.tick(SimTime::from_micros(e * epoch.as_micros()), epoch);
                    if e > epochs / 2 {
                        sum += lat;
                        n += 1;
                    }
                }
                let under = sum / n as f64;
                pt.row(vec![
                    kind.to_string(),
                    ms(*base),
                    ms(under),
                    pct((under / base - 1.0).max(0.0)),
                ]);
            }
            producer_table = Some(pt);
        }

        println!(
            "  [{}% remote] leased {:.1} GB across producers; predictor backend: {}",
            (remote * 100.0) as u32,
            with.leased_bytes() as f64 / (1u64 << 30) as f64,
            if with.broker.predictor.is_pjrt() { "PJRT" } else { "fallback" },
        );
    }

    println!("\nTable 2a — consumer latencies (paper: 1.6-2.8x improvement):");
    table.print();
    println!("\nTable 2b — producer impact (paper: 0.0-2.1% degradation):");
    producer_table.unwrap().print();
    println!("\ncluster_deploy OK");
}

//! Market simulation example — paper §7.4 (Fig 12 & Fig 13): 10,000
//! consumers with MemCachier-style MRCs trade against trace-driven
//! supply under the three pricing strategies, with the price search
//! evaluated through the AOT demand artifact when built.
//!
//! Run: `cargo run --release --example market_sim [-- --quick]`

use memtrade::broker::pricing::PricingStrategy;
use memtrade::core::Money;
use memtrade::util::fmt::{pct, Table};
use memtrade::sim::market::{MarketSim, MarketSimConfig};
use memtrade::workload::cluster_trace::{ClusterTrace, MachineClass};
use memtrade::workload::memcachier::MrcLibrary;
use memtrade::workload::spot::SpotPriceSeries;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n = if quick { 2_000 } else { 10_000 };
    let steps = if quick { 144 } else { 576 };
    println!("== Memtrade market: {n} consumers, {steps} five-minute steps ==\n");

    let spot = SpotPriceSeries::r3_large(steps, 43);
    // Supply from Google-trace idle memory, 5 GB per unit (§7.4).
    let trace = ClusterTrace::generate(MachineClass::Google, 200, steps, 288, 45);
    let supply: Vec<f64> = (0..steps)
        .map(|t| trace.machines.iter().map(|m| (1.0 - m.mem[t]).max(0.0)).sum::<f64>() * 5.0)
        .collect();

    let lib = MrcLibrary::paper_population(7);
    let mut table = Table::new(vec![
        "strategy",
        "mean price ($/slab·h)",
        "total revenue ($)",
        "mean utilization",
        "rel. hit gain",
        "consumer saving vs spot",
    ]);
    for (name, strategy) in [
        ("fixed (1/4 spot)", PricingStrategy::FixedFraction),
        ("max volume", PricingStrategy::MaxVolume),
        ("max revenue", PricingStrategy::MaxRevenue),
    ] {
        let cfg = MarketSimConfig {
            n_consumers: n,
            strategy,
            seed: 23,
            max_slabs: 64,
            eviction_probability: 0.0,
        };
        let mut sim = MarketSim::new(cfg, &lib, Money::from_dollars(0.00001));
        let mut revenue = 0.0;
        let mut price_sum = 0.0;
        let mut util_sum = 0.0;
        let mut hit_sum = 0.0;
        let mut save_sum = 0.0;
        for t in 0..steps {
            let s = sim.step(supply[t], &spot, t);
            revenue += s.revenue;
            price_sum += s.price_per_slab_hour;
            util_sum += s.utilization;
            hit_sum += s.rel_hit_improvement;
            save_sum += s.cost_saving_vs_spot;
        }
        table.row(vec![
            name.to_string(),
            format!("{:.7}", price_sum / steps as f64),
            format!("{revenue:.2}"),
            pct(util_sum / steps as f64),
            pct(hit_sum / steps as f64),
            pct(save_sum / steps as f64),
        ]);
        println!(
            "  {name}: demand engine epochs={} (PJRT evaluated when artifacts present)",
            sim.pricing.epochs
        );
    }
    println!();
    table.print();
    println!(
        "\n(paper §7.4: >16% relative hit-ratio gain; consumer cost ~82% below spot;\n \
         cluster utilization raised toward ~98% under local-search pricing)\n"
    );
    println!("market_sim OK");
}

//! Tour of the networked marketplace: a broker daemon, two producer
//! agents, and a lease-aware consumer pool, all over real TCP in one
//! process — then a producer failure mid-run, absorbed as cache misses
//! and healed by automatic re-provisioning.
//!
//! Run with: `cargo run --release --example marketplace`

use memtrade::consumer::client::SecureKv;
use memtrade::core::config::BrokerConfig;
use memtrade::core::SimTime;
use memtrade::market::{
    BrokerServer, BrokerServerConfig, ProducerAgent, ProducerAgentConfig, RemotePool,
    RemotePoolConfig,
};
use std::time::{Duration, Instant};

fn main() {
    const SLAB: u64 = 1 << 20; // 1 MB slabs so the tour is instant

    println!("=== 1. broker daemon ===");
    let broker = BrokerServer::start(
        "127.0.0.1:0",
        BrokerConfig {
            slab_bytes: SLAB,
            min_lease: SimTime::from_secs(10),
            ..Default::default()
        },
        BrokerServerConfig {
            tick: Duration::from_millis(20),
            producer_timeout: Duration::from_millis(400),
            forecast_min_samples: usize::MAX,
            ..Default::default()
        },
    )
    .unwrap();
    println!("broker listening on {} (control plane)", broker.addr());

    println!("\n=== 2. producer agents register and heartbeat ===");
    let mk_agent = |id: u64| {
        ProducerAgent::start(ProducerAgentConfig {
            producer: id,
            brokers: vec![broker.addr().to_string()],
            data_addr: "127.0.0.1:0".to_string(),
            advertise: None,
            capacity_bytes: 32 * SLAB,
            harvest: false,
            heartbeat: Duration::from_millis(50),
            shards: 2,
            rate_bps: None,
            seed: id,
            ..Default::default()
        })
        .unwrap()
    };
    let mut agents = vec![mk_agent(1), mk_agent(2)];
    for a in &agents {
        println!("producer agent up, data plane at {}", a.data_addr());
    }

    println!("\n=== 3. consumer pool leases slabs ===");
    let mut pool = RemotePool::connect(RemotePoolConfig {
        consumer: 9,
        brokers: vec![broker.addr().to_string()],
        target_slabs: 48,
        lease_ttl: Duration::from_secs(10),
        renew_margin: Duration::from_secs(3),
        ..Default::default()
    })
    .unwrap();
    // Wait until the grants are held AND the producer stores have grown
    // to their lease targets (that happens on the next heartbeat ack —
    // PUTs before it would bounce off a zero-budget store).
    let t_mount = Instant::now();
    loop {
        let stores_ready = agents.iter().all(|a| {
            let max = a.store().map(|s| s.max_bytes()).unwrap_or(0) as u64;
            max == a.target_bytes() && max > 0
        });
        if pool.held_slabs() >= 48 && stores_ready {
            break;
        }
        if t_mount.elapsed() > Duration::from_secs(10) {
            eprintln!(
                "gave up waiting for capacity ({} slabs held)",
                pool.held_slabs()
            );
            return;
        }
        pool.maintain();
        std::thread::sleep(Duration::from_millis(10));
    }
    println!(
        "holding {} slabs across {} leases: {:?}",
        pool.held_slabs(),
        pool.live_slots(),
        pool.live_endpoints()
    );

    println!("\n=== 4. secure traffic over leased memory ===");
    let mut secure = SecureKv::new(Some([7u8; 16]), true, 1);
    let value = vec![0xAB_u8; 512];
    let n = 2_000u32;
    let t0 = Instant::now();
    for i in 0..n {
        assert!(secure.put(&mut pool, format!("key{i}").as_bytes(), &value));
    }
    let mut hits = 0;
    for i in 0..n {
        if secure.get(&mut pool, format!("key{i}").as_bytes()).is_some() {
            hits += 1;
        }
    }
    println!(
        "{n} PUTs + {n} GETs in {:.0} ms, hit ratio {:.3}",
        t0.elapsed().as_secs_f64() * 1e3,
        hits as f64 / n as f64
    );
    for a in &agents {
        println!(
            "producer {} store: {} entries, {} MB budget",
            a.data_addr(),
            a.store().map(|s| s.len()).unwrap_or(0),
            a.target_bytes() >> 20
        );
    }

    println!("\n=== 5. producer failure mid-run ===");
    println!("killing producer {} (no deregister — a crash)", agents[0].data_addr());
    agents[0].kill();
    let t1 = Instant::now();
    let mut misses = 0;
    let mut survived = 0;
    for i in 0..n {
        match secure.get(&mut pool, format!("key{i}").as_bytes()) {
            Some(_) => survived += 1,
            None => misses += 1,
        }
    }
    println!(
        "first sweep after kill: {survived} hits, {misses} misses, \
         {} integrity failures (lost memory is a miss, never an error)",
        secure.stats.integrity_failures
    );
    while pool.distinct_endpoints().len() != 1 || pool.held_slabs() < 32 {
        pool.maintain();
        std::thread::sleep(Duration::from_millis(10));
        if t1.elapsed() > Duration::from_secs(10) {
            break;
        }
    }
    println!(
        "re-provisioned in {:.0} ms: {} slabs on {:?}",
        t1.elapsed().as_secs_f64() * 1e3,
        pool.held_slabs(),
        pool.live_endpoints()
    );
    for i in 0..n {
        if secure.get(&mut pool, format!("key{i}").as_bytes()).is_none() {
            let _ = secure.put(&mut pool, format!("key{i}").as_bytes(), &value);
        }
    }
    let mut healed = 0;
    for i in 0..n {
        if secure.get(&mut pool, format!("key{i}").as_bytes()).is_some() {
            healed += 1;
        }
    }
    println!("after refill: {healed}/{n} keys hit again");
    println!(
        "pool stats: grants {}, renewals {}, slots lost {}, re-requests {}",
        pool.stats.grants.get(),
        pool.stats.renewals.get(),
        pool.stats.slots_lost.get(),
        pool.stats.rerequests.get()
    );

    drop(pool);
    agents.remove(1).stop();
    broker.stop();
    println!("\ndone.");
}
